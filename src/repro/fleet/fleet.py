"""Serving fleet: N engine replicas behind one router and one registry.

This is the layer the ROADMAP's north star asks for — a front-end that
turns a request *stream* into batched work across engine replicas — built
so the paper's economics compose at scale:

* **One registry, N replicas** — every replica resolves through its own
  :class:`~repro.core.resolution.ResolutionPipeline` over a *shared*
  :class:`~repro.service.TuningService` (per hardware target) and the one
  :class:`~repro.service.ScheduleRegistry`.  A background publish triggered
  by traffic on any replica reaches every replica through the existing
  generation check at its next decode-step boundary — no fleet-level
  invalidation protocol, and zero cross-replica schedule divergence
  (:meth:`ServingFleet.schedule_mismatches` asserts it).
* **Demand-driven tuning** — the router's :class:`~repro.fleet.demand.\
DemandTracker` aggregates per-prefill-bucket arrival counts; the fleet
  prefetches tuning jobs for the hottest *unresolved* buckets
  (:meth:`~repro.service.TuningService.prefetch`, priority = arrival
  count), so hot shapes graduate default → transfer → exact first and cold
  shapes never spend budget.
* **Virtual-time simulation** — replica step durations come from the cost
  model (the resolved plan's kernel seconds), so schedule quality feeds
  straight into latency/throughput: a replica serving exact-tier schedules
  finishes its steps sooner, drains its queue faster, and sheds less.  The
  engines still run *real* (jitted) prefill/decode steps — tokens, caches,
  replans, and plan propagation are the production code paths, only the
  clock is simulated.

Heterogeneous fleets are supported by giving replicas different hardware
targets (``targets=[...]`` from :mod:`repro.targets`): replicas sharing a
target share a TuningService (one namespace), targets never leak into each
other, and ``donor_target`` lets e.g. edge replicas transfer from the
server-tuned pool.

The replica set is *elastic* (DESIGN.md §9): :meth:`ServingFleet.\
add_replica` warm-joins a replica whose plan resolves at the current shared
registry generation (it inherits every published exact-tier schedule before
its first request), :meth:`ServingFleet.retire_replica` drain-retires one
(no new dispatch, in-flight work finishes, engine-queued work is re-routed,
pending tuning jobs are cancelled), and an attached
:class:`~repro.fleet.autoscale.Autoscaler` drives both from windowed
telemetry inside :meth:`ServingFleet.serve`.
"""
from __future__ import annotations

import json
from typing import Any, Sequence

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.extract import extract_kernels
from repro.core.resolution import Resolution, spec_verify_uses
from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.schedule import ScheduleInvalid
from repro.core.workload import KernelInstance, KernelUse
from repro.fleet.acceptance import AcceptanceTracker
from repro.fleet.advisor import TuningAdvisor
from repro.fleet.demand import DemandTracker
from repro.fleet.metrics import FleetMetrics
from repro.fleet.router import TIER_SCORE, QueueFull, RequestRouter
from repro.fleet.traffic import FleetRequest
from repro.kernels.ops import ScheduleProvider
from repro.obs import (NULL_TRACER, MetricsRegistry, SLOMonitor,
                       SpeedupLedger, default_slos)
from repro.serving import PagedServingEngine, ServingEngine
from repro.serving.speculative import expected_committed_tokens
from repro.serving.speculative import spec_gain as _spec_gain
from repro.targets import DEFAULT_TARGET, target_name


class Replica:
    """One :class:`ServingEngine` behind the router, with a virtual clock.

    ``time`` is the virtual instant the replica's current work (a prefill or
    a batched decode step) finishes; ``step_pending`` marks that a decode
    step must actually execute (``engine.step()``) when that instant is
    reached.  Step costs are summed from the engine's execution plan through
    the service's runner and memoized per plan generation — an upgrade that
    lands mid-stream speeds the very next step up.
    """

    def __init__(self, idx: int, cfg: ArchConfig, engine: ServingEngine,
                 service=None, target: str = DEFAULT_TARGET):
        self.idx = idx
        self.cfg = cfg
        self.engine = engine
        self.service = service
        self.target = target
        # Observability rides the engine's binding (the fleet sets it before
        # wrapping); bare engines fall back to the no-op tracer.
        self.tracer = getattr(engine, "tracer", NULL_TRACER)
        self.track = getattr(engine, "trace_track", f"replica-{idx}")
        self.time = 0.0
        self.busy = False
        self.step_pending = False
        self._step_t0 = 0.0
        self.requests_admitted = 0
        # Lifecycle: active (serving) -> draining (no new dispatch, in-flight
        # finishing) -> retired (empty, clock stopped).  Indices are stable:
        # a retired replica keeps its slot in the fleet's list.
        self.state = "active"
        self.joined_s = 0.0
        self.retired_s: float | None = None
        self._runner = (service.runner if service is not None
                        else CachedRunner(AnalyticalRunner(target)))
        self._mode = service.mode if service is not None else "strict"
        self._fleet_reqs: dict[int, FleetRequest] = {}  # engine uid -> request
        self._decode_uses = self._serving_uses()
        self._bucket_uses: dict[int, list[KernelUse]] = {}
        # Plan-derived memos, valid for exactly one plan generation: a
        # re-plan drops them wholesale, so a long-lived replica never
        # accumulates entries for superseded generations.
        self._caches_gen: int | None = None
        self._cost_cache: dict[Any, float] = {}
        self._score_cache: dict[int, tuple[float, float]] = {}
        self._workload_cache: dict[str, list] = {}
        #: Observed cell executions (``prefill:<bucket>``, ``decode``, the
        #: paged spec cells ...) — the live critical-path signal the
        #: profiler, ledger, and TuningAdvisor read without a tracer.
        self.cell_counts: dict[str, float] = {}
        self._cell_emitted: dict[str, int] = {}  # cell -> plan generation

    def _serving_uses(self) -> list[KernelUse]:
        """Kernels of this engine's batched decode cell (subclass hook)."""
        return extract_kernels(
            self.cfg, ShapeConfig("serve_decode", self.engine.max_len,
                                  self.engine.slots, "decode"), dp=1, tp=1)

    # -- surfaces the router sees ---------------------------------------------
    @property
    def free_slots(self) -> int:
        return self.engine.free_slots

    @property
    def dispatchable(self) -> bool:
        """Whether the router may send *new* work here (active only)."""
        return self.state == "active"

    def utilization(self) -> float:
        return self.engine.utilization()

    def bucket_for(self, prompt_len: int) -> int:
        return self.engine.bucket_for(min(prompt_len, self.engine.max_len))

    def prefill_tier_score(self, prompt_len: int) -> float:
        """Mean tier quality (exact=3 .. default=0) of this replica's plan
        over the prompt's prefill-bucket kernels — what plan-aware routing
        ranks replicas by."""
        return self._bucket_quality(self.bucket_for(prompt_len))[0]

    def prefill_exact_share(self, bucket: int) -> float:
        """Fraction of the bucket's kernels resolved at the exact tier."""
        return self._bucket_quality(bucket)[1]

    # -- plan-derived costs ----------------------------------------------------
    def _generation(self) -> int:
        return self.engine.plan.generation if self.engine.plan is not None else -1

    def _resolution(self, inst: KernelInstance) -> Resolution:
        plan = self.engine.plan
        res = plan.lookup(inst) if plan is not None else None
        if res is None:  # outside the plan: the pipeline memo answers
            res = self.engine.provider.pipeline.resolve(inst)
        return res

    @property
    def decode_uses(self) -> list[KernelUse]:
        """Kernels of the batched decode step (every request exercises them)."""
        return self._decode_uses

    def prefill_uses(self, bucket: int) -> list[KernelUse]:
        uses = self._bucket_uses.get(bucket)
        if uses is None:
            uses = self._bucket_uses[bucket] = extract_kernels(
                self.cfg, ShapeConfig(f"serve_prefill_{bucket}", bucket, 1,
                                      "prefill"), dp=1, tp=1)
        return uses

    def _fresh_caches(self) -> None:
        gen = self._generation()
        if gen != self._caches_gen:
            self._cost_cache.clear()
            self._score_cache.clear()
            self._workload_cache.clear()
            self._caches_gen = gen

    def _uses_cost(self, uses: Sequence[KernelUse], cache_key: Any) -> float:
        self._fresh_caches()
        cost = self._cost_cache.get(cache_key)
        if cost is None:
            cost = 0.0
            for u in uses:
                sched = self._resolution(u.instance).schedule
                try:
                    secs = self._runner.seconds(u.instance, sched,
                                                mode=self._mode)
                except ScheduleInvalid:
                    secs = self._runner.seconds(u.instance, None)
                cost += u.use_count * secs
            self._cost_cache[cache_key] = cost
        return cost

    def _bucket_quality(self, bucket: int) -> tuple[float, float]:
        self._fresh_caches()
        q = self._score_cache.get(bucket)
        if q is None:
            uses = self.prefill_uses(bucket)
            tiers = [self._resolution(u.instance).tier for u in uses]
            score = sum(TIER_SCORE[t] for t in tiers) / len(tiers)
            exact = sum(1 for t in tiers if t == "exact") / len(tiers)
            q = self._score_cache[bucket] = (score, exact)
        return q

    def decode_cost(self) -> float:
        """Virtual seconds one batched decode step takes under the plan."""
        return self._uses_cost(self._decode_uses, "decode")

    def prefill_cost(self, bucket: int) -> float:
        return self._uses_cost(self.prefill_uses(bucket), ("prefill", bucket))

    def untuned_decode_cost(self) -> float:
        return sum(u.use_count * self._runner.seconds(u.instance, None)
                   for u in self._decode_uses)

    # -- cell accounting (critical-path attribution) ---------------------------
    def cell_uses(self, cell: str) -> list[KernelUse]:
        """Kernel uses of one cost cell, by its counter id."""
        if cell == "decode":
            return self._decode_uses
        kind, _, arg = cell.partition(":")
        if kind == "prefill":
            return self.prefill_uses(int(arg))
        raise KeyError(f"unknown cell {cell!r}")

    def use_resolution(self, inst: KernelInstance) -> Resolution:
        """Public view of the plan's resolution for one kernel instance."""
        return self._resolution(inst)

    def use_seconds(self, inst: KernelInstance, schedule) -> float:
        """Per-call seconds of ``inst`` under ``schedule`` (None/invalid ->
        untuned) — the same pricing ``_uses_cost`` charges the clock."""
        if schedule is not None:
            try:
                return self._runner.seconds(inst, schedule, mode=self._mode)
            except ScheduleInvalid:
                pass
        return self._runner.seconds(inst, None)

    def cell_workload_seconds(self, cell: str) -> "list[tuple[KernelUse, float]]":
        """Per-execution seconds of each workload in ``cell`` under the
        current plan (``use_count`` folded in, so the pairs sum to exactly
        what one execution charges the virtual clock).  Memoized per plan
        generation alongside the cost caches."""
        self._fresh_caches()
        rows = self._workload_cache.get(cell)
        if rows is None:
            rows = self._workload_cache[cell] = [
                (u, u.use_count * self.use_seconds(
                    u.instance, self._resolution(u.instance).schedule))
                for u in self.cell_uses(cell)]
        return rows

    def _note_cell(self, cell: str, n: float, now: float) -> None:
        """Count ``n`` executions of ``cell`` at the instant its cost is
        charged.  When tracing, (re-)emit the cell's workload mapping once
        per plan generation — the ``cell_workloads`` events the offline
        profiler joins replica spans against."""
        self.cell_counts[cell] = self.cell_counts.get(cell, 0) + n
        if self.tracer.enabled:
            gen = self._generation()
            if self._cell_emitted.get(cell) != gen:
                self._cell_emitted[cell] = gen
                self.tracer.event(
                    "cell_workloads", self.track, t=now, cell=cell,
                    generation=gen,
                    workloads=[[u.instance.workload_key(), s]
                               for u, s in self.cell_workload_seconds(cell)])

    # -- lifecycle -------------------------------------------------------------
    def admit(self, req: FleetRequest, now: float):
        """Admit into the engine and charge the prefill to the clock."""
        engine_req = self.engine.add_request(
            req.prompt, max_new_tokens=req.max_new_tokens, eos_id=req.eos_id)
        req.admitted_s = now
        req.replica = self.idx
        req.exact_share_at_admit = self.prefill_exact_share(req.bucket)
        self.requests_admitted += 1
        t0 = max(self.time, now)
        self._note_cell(f"prefill:{req.bucket}", 1, t0)
        self.time = t0 + self.prefill_cost(req.bucket)
        # The slot engine prefills synchronously: the first token exists
        # the instant the prefill's virtual time elapses.
        req.prefill_done_s = self.time
        if self.tracer.enabled:
            self.tracer.add_span("prefill", self.track, t0, self.time,
                                 uid=req.uid, bucket=req.bucket,
                                 target=self.target)
        self.busy, self.step_pending = True, False
        if not engine_req.done:
            self._fleet_reqs[engine_req.uid] = req
        return engine_req

    def complete_step(self, now: float) -> list[FleetRequest]:
        """Run the decode step that virtually ends at ``now``."""
        finished = self.engine.step()
        self.busy = self.step_pending = False
        out = []
        for er in finished:
            fr = self._fleet_reqs.pop(er.uid)
            fr.tokens = len(er.generated)
            fr.generated = list(er.generated)
            out.append(fr)
        if self.tracer.enabled:
            self.tracer.add_span("decode_step", self.track, self._step_t0,
                                 now, active=len(self.engine.active),
                                 finished=len(out))
        return out

    def start_step(self, now: float) -> None:
        self._note_cell("decode", 1, now)
        self.time = now + self.decode_cost()
        self.busy, self.step_pending = True, True
        self._step_t0 = now

    def stats(self) -> dict:
        plan = self.engine.plan
        return {
            "target": self.target,
            "state": self.state,
            "joined_s": self.joined_s,
            "retired_s": self.retired_s,
            "requests": self.requests_admitted,
            "replans": self.engine.replans,
            "utilization": self.utilization(),
            "plan_tiers": plan.tier_counts() if plan is not None else {},
            "plan_generation": plan.generation if plan is not None else None,
            "prefill_traces": self.engine.prefill_trace_count,
        }


class PagedReplica(Replica):
    """A :class:`~repro.serving.PagedServingEngine` behind the router.

    Everything follows from iteration-level admission: ``admit`` only
    enqueues (no synchronous prefill, so no time is charged — the request's
    chunks are billed inside the steps that run them); a step's cost is the
    engine's *planned* work for that iteration — the ``chunk_prefill``
    cells it will run plus the batched decode cell — so prefill and decode
    share the virtual clock exactly the way they share the iteration.
    ``expected_step_s`` exposes the same estimate to deadline-aware routing
    *before* the step starts (the scheduler is pure, so preview and
    execution always agree).

    When the engine speculates, the cost model grows three more cells —
    the draft's chunked prefill (keeping the draft cache in sync), the
    draft's batched decode (k+1 per burst), and the batched ``verify``
    step — and the iteration cost sums exactly what ``planned_work`` says
    will run.  The fleet installs ``acceptance`` (the per-class
    :class:`~repro.fleet.acceptance.AcceptanceTracker`) plus the
    acceptance gauge / committed histogram; ``complete_step`` drains the
    engine's burst events into them.
    """

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        # Fleet-installed speculative collaborators (None: speculation off).
        self.acceptance: AcceptanceTracker | None = None
        self.spec_gauge = None
        self.spec_hist = None
        self.spec_counters = None
        self._verify_uses: list[KernelUse] | None = None
        self._draft_uses: list[KernelUse] | None = None
        self._draft_chunk_uses: dict[int, list[KernelUse]] = {}

    def _serving_uses(self) -> list[KernelUse]:
        e = self.engine
        return extract_kernels(
            self.cfg, ShapeConfig("paged_decode", e.max_ctx, e.decode_batch,
                                  "decode"), dp=1, tp=1)

    def bucket_for(self, prompt_len: int) -> int:
        return self.engine.bucket_for(prompt_len)

    def prefill_uses(self, bucket: int) -> list[KernelUse]:
        # "bucket" is a chunk length here: the same chunk_prefill cell the
        # plan (:func:`plan_serving_paged`) froze for that length.
        uses = self._bucket_uses.get(bucket)
        if uses is None:
            uses = self._bucket_uses[bucket] = extract_kernels(
                self.cfg, ShapeConfig(f"paged_chunk_{bucket}", bucket, 1,
                                      "chunk_prefill",
                                      ctx_len=self.engine.max_ctx), dp=1, tp=1)
        return uses

    # -- speculative cost cells -------------------------------------------------
    @property
    def spec_capable(self) -> bool:
        """Whether the wrapped engine has a draft attached (speculation on)."""
        return bool(getattr(self.engine, "_spec", False))

    def verify_cell_uses(self) -> list[KernelUse]:
        if self._verify_uses is None:
            e = self.engine
            self._verify_uses = spec_verify_uses(
                self.cfg, decode_batch=e.decode_batch, max_ctx=e.max_ctx,
                spec_k=e.spec_k)
        return self._verify_uses

    def draft_decode_uses(self) -> list[KernelUse]:
        if self._draft_uses is None:
            e = self.engine
            self._draft_uses = extract_kernels(
                e.draft_model.cfg,
                ShapeConfig("draft_decode", e.max_ctx, e.decode_batch,
                            "decode"), dp=1, tp=1)
        return self._draft_uses

    def verify_cost(self) -> float:
        """Virtual seconds of one batched verify step (all lanes, k+1 each)."""
        return self._uses_cost(self.verify_cell_uses(), "verify")

    def draft_decode_cost(self) -> float:
        """Virtual seconds of one batched draft decode step."""
        return self._uses_cost(self.draft_decode_uses(), "draft_decode")

    def draft_chunk_uses(self, c: int) -> list[KernelUse]:
        uses = self._draft_chunk_uses.get(c)
        if uses is None:
            uses = self._draft_chunk_uses[c] = extract_kernels(
                self.engine.draft_model.cfg,
                ShapeConfig(f"draft_chunk_{c}", c, 1, "chunk_prefill",
                            ctx_len=self.engine.max_ctx), dp=1, tp=1)
        return uses

    def draft_chunk_cost(self, c: int) -> float:
        return self._uses_cost(self.draft_chunk_uses(c), ("draft_chunk", c))

    def cell_uses(self, cell: str) -> list[KernelUse]:
        if cell == "verify":
            return self.verify_cell_uses()
        if cell == "draft_decode":
            return self.draft_decode_uses()
        kind, _, arg = cell.partition(":")
        if kind == "draft_sync":
            return self.draft_chunk_uses(int(arg))
        return super().cell_uses(cell)

    def spec_gain(self, alpha: float) -> float:
        """Projected speculate-vs-plain throughput ratio at acceptance rate
        ``alpha``, under this replica's *measured* (plan-derived) cell
        costs — the admit-time decision quantity for ``speculative="auto"``."""
        if not self.spec_capable:
            return 1.0
        return _spec_gain(self.engine.spec_k, alpha,
                          draft_cost_s=self.draft_decode_cost(),
                          verify_cost_s=self.verify_cost(),
                          decode_cost_s=self.decode_cost())

    def expected_token_s(self, request_class: str = "") -> float | None:
        """Expected virtual seconds per *committed* token for a request of
        ``request_class`` (None when not speculating — callers fall back to
        per-step projections).  Auto routing takes the better of the spec
        burst rate at the class's current acceptance estimate and plain
        decode, which is exactly what admission will choose."""
        if not self.spec_capable:
            return None
        alpha = (self.acceptance.alpha(request_class)
                 if self.acceptance is not None else 0.7)
        k = self.engine.spec_k
        burst = (k + 1) * self.draft_decode_cost() + self.verify_cost()
        spec_tok = burst / expected_committed_tokens(k, alpha)
        return min(self.decode_cost(), spec_tok)

    def _work_cost(self, work: dict) -> float:
        cost = sum(self.prefill_cost(c) for c in work["chunk_lens"])
        cost += sum(self.draft_chunk_cost(c)
                    for c in work.get("draft_sync_lens", ()))
        if work.get("spec_lanes"):
            cost += (work["draft_steps"] * self.draft_decode_cost()
                     + self.verify_cost())
        if work["decode"]:
            cost += self.decode_cost()
        # nothing runnable this instant (e.g. pure preemption step): charge
        # a decode step so the clock always advances
        return cost if cost > 0.0 else self.decode_cost()

    def expected_step_s(self) -> float:
        """Virtual cost of the engine's next iteration under the plan."""
        return self._work_cost(self.engine.planned_work())

    def admit(self, req: FleetRequest, now: float):
        """Enqueue into the engine — O(1), no clock charge, no busy flag:
        the admitted request's first chunk runs inside the next step.

        ``req.speculative`` carries the fleet's admit-time spec decision
        (None defers to the engine default); the workload class rides along
        so burst events can be attributed back to the class."""
        engine_req = self.engine.add_request(
            req.prompt, max_new_tokens=req.max_new_tokens, eos_id=req.eos_id,
            speculative=req.speculative, request_class=req.request_class)
        req.admitted_s = now
        req.replica = self.idx
        req.exact_share_at_admit = self.prefill_exact_share(req.bucket)
        self.requests_admitted += 1
        self._fleet_reqs[engine_req.uid] = req
        return engine_req

    def complete_step(self, now: float) -> list[FleetRequest]:
        """Run the iteration that virtually ends at ``now``.

        The engine's scheduler is pure, so previewing ``planned_work()``
        here sees exactly the chunks and decode lanes the step is about to
        run — the preview lays the iteration's child spans out on the
        virtual clock (chunks sequentially, then the batched decode), and
        marks each request's first-token instant for TTFT accounting.
        """
        tracing = self.tracer.enabled
        work = self.engine.planned_work() if tracing else None
        finished = self.engine.step()
        self.busy = self.step_pending = False
        spec_events = (self.engine.drain_spec_events()
                       if self.spec_capable else [])
        for ev in spec_events:
            if self.acceptance is not None:
                self.acceptance.record(ev["request_class"], ev["proposed"],
                                       ev["accepted"], now)
            if self.spec_hist is not None:
                self.spec_hist.observe(ev["committed"])
            if self.spec_counters is not None:
                self.spec_counters.inc("bursts")
                self.spec_counters.inc("proposed", ev["proposed"])
                self.spec_counters.inc("accepted", ev["accepted"])
                self.spec_counters.inc("committed", ev["committed"])
        if spec_events and self.spec_gauge is not None:
            prop = sum(e["proposed"] for e in spec_events)
            if prop:
                self.spec_gauge.sample(
                    sum(e["accepted"] for e in spec_events) / prop, now)
        out = []
        for er in finished:
            fr = self._fleet_reqs.pop(er.uid)
            fr.tokens = len(er.generated)
            fr.generated = list(er.generated)
            if fr.prefill_done_s is None:
                fr.prefill_done_s = now
            out.append(fr)
        # First generated token for requests still in flight: their prefill
        # chunks all ran inside this iteration.
        active = self.engine.active
        for uid, fr in self._fleet_reqs.items():
            if fr.prefill_done_s is None:
                er = active.get(uid)
                if er is not None and er.generated:
                    fr.prefill_done_s = now
        if tracing:
            parent = self.tracer.add_span(
                "step", self.track, self._step_t0, now,
                chunks=len(work["chunk_lens"]), decode=work["decode"],
                spec_lanes=work.get("spec_lanes", 0),
                active=len(active), finished=len(out))
            # Child spans re-derive the step layout from the same costs
            # start_step charged; clamp to ``now`` against float drift.
            t = self._step_t0
            for c in work["chunk_lens"]:
                t1 = min(t + self.prefill_cost(c), now)
                self.tracer.add_span("chunk", self.track, min(t, t1), t1,
                                     parent=parent, len=c)
                t = t1
            for c in work.get("draft_sync_lens", ()):
                t1 = min(t + self.draft_chunk_cost(c), now)
                self.tracer.add_span("draft_sync", self.track, min(t, t1), t1,
                                     parent=parent, len=c)
                t = t1
            if work.get("spec_lanes"):
                t1 = min(t + work["draft_steps"] * self.draft_decode_cost(),
                         now)
                self.tracer.add_span("draft_burst", self.track, min(t, t1),
                                     t1, parent=parent,
                                     lanes=work["spec_lanes"],
                                     steps=work["draft_steps"])
                t = t1
                t1 = min(t + self.verify_cost(), now)
                self.tracer.add_span("verify", self.track, min(t, t1), t1,
                                     parent=parent,
                                     lanes=work["spec_lanes"],
                                     len=work["verify_len"])
                t = t1
            if work["decode"]:
                t1 = max(t, min(t + self.decode_cost(), now))
                self.tracer.add_span("decode", self.track, t, t1,
                                     parent=parent)
        return out

    def start_step(self, now: float) -> None:
        # Count the iteration's cells at the instant their cost is charged
        # (the scheduler is pure and no admissions land mid-step, so the
        # preview here is exactly what complete_step will run and trace).
        work = self.engine.planned_work()
        for c in work["chunk_lens"]:
            self._note_cell(f"prefill:{c}", 1, now)
        for c in work.get("draft_sync_lens", ()):
            self._note_cell(f"draft_sync:{c}", 1, now)
        if work.get("spec_lanes"):
            self._note_cell("draft_decode", work["draft_steps"], now)
            self._note_cell("verify", 1, now)
        if work["decode"]:
            self._note_cell("decode", 1, now)
        self.time = now + self._work_cost(work)
        self.busy, self.step_pending = True, True
        self._step_t0 = now

    def stats(self) -> dict:
        out = super().stats()
        out["engine"] = "paged"
        out["preemptions"] = self.engine.preemptions
        out["defrags"] = self.engine.defrags
        out["page_utilization"] = self.engine.utilization()
        if self.spec_capable:
            e = self.engine
            out["spec"] = {
                "k": e.spec_k, "bursts": e.spec_bursts,
                "proposed": e.spec_proposed, "accepted": e.spec_accepted,
                "committed": e.spec_committed,
                "alpha": e.spec_accepted / max(e.spec_proposed, 1)}
        return out


class ServingFleet:
    """Router + demand tracker + N plan-aware engine replicas.

    ``registry`` is the shared :class:`~repro.service.ScheduleRegistry`
    (None serves everything untuned — no services, plans stay default-tier).
    ``targets`` assigns one hardware target per replica (a single name
    applies to all); replicas sharing a target share one TuningService.
    Background tuning is deterministic: services run ``max_workers=0`` and
    the fleet drains ``drain_jobs`` jobs every ``drain_every`` events —
    publishes arrive in bursts, so re-plans stay bounded by bursts rather
    than by publishes.

    ``engine`` selects the replica engine: ``"slot"`` (the fixed-slot
    baseline) or ``"paged"`` (iteration-level continuous batching over a
    paged KV pool — ``decode_batch``/``page_size``/``pool_pages``/``chunk``
    parameterize it; ``max_len`` becomes the per-request ``max_ctx``;
    ``slots`` is ignored in favor of ``decode_batch``).
    """

    def __init__(self, cfg: ArchConfig, model, params, *, replicas: int = 2,
                 slots: int = 2, max_len: int = 64,
                 engine: str = "slot", decode_batch: int | None = None,
                 page_size: int = 8, pool_pages: int | None = None,
                 chunk: int = 8, chunks_per_step: int | None = None,
                 admit_cap: int | None = None,
                 defrag_threshold: float | None = None,
                 registry=None, policy: str = "round_robin",
                 queue_cap: int = 32, prefetch: "bool | str" = False,
                 prefetch_buckets: int = 2,
                 targets: "Sequence[str] | str | None" = None,
                 donor_target: str | None = None,
                 donors: Sequence[str] | None = None,
                 tuning_budget_s: float = float("inf"),
                 drain_jobs: int = 2, drain_every: int = 4,
                 autoscaler=None, min_replicas: int = 1,
                 seed: int = 0, extras: dict | None = None,
                 speculative: "bool | str" = False, draft_model=None,
                 draft_params=None, spec_k: int = 4,
                 acceptance: "AcceptanceTracker | None" = None,
                 tracer=None, metrics: MetricsRegistry | None = None,
                 slos=None, slo_window_s: float | None = None,
                 advisor: "TuningAdvisor | None" = None):
        if engine not in ("slot", "paged"):
            raise ValueError(f"unknown engine {engine!r}: 'slot' or 'paged'")
        if prefetch not in (False, True, "advisor"):
            raise ValueError(
                f"prefetch must be False, True, or 'advisor', got {prefetch!r}")
        self.engine_kind = engine
        if replicas <= 0:
            raise ValueError("need at least one replica")
        if speculative not in (False, True, "auto"):
            raise ValueError("speculative must be False, True, or 'auto'")
        if speculative:
            if engine != "paged":
                raise ValueError("speculative serving requires engine='paged'")
            if draft_model is None or draft_params is None:
                raise ValueError(
                    "speculative serving needs draft_model and draft_params")
            if spec_k <= 0:
                raise ValueError("spec_k must be positive when speculating")
        self.spec_mode = speculative
        self.acceptance = ((acceptance if acceptance is not None
                            else AcceptanceTracker()) if speculative else None)
        self.cfg = cfg
        self.registry = registry
        # Observability first: services and replicas constructed below bind
        # to the fleet tracer/registry, and the tracer's clock closes over
        # ``_now`` (the discrete-event virtual instant).
        self._now = 0.0
        self.obs = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled:
            self.tracer.set_clock(lambda: self._now)
            for i in range(replicas):  # display order: replicas first
                self.tracer.track(f"replica-{i}")
            self.tracer.track("router")
            self.tracer.track("autoscaler")
        self.prefetch = prefetch
        self.prefetch_buckets = prefetch_buckets
        self.drain_jobs = drain_jobs
        self.drain_every = drain_every
        self.autoscaler = autoscaler
        self.min_replicas = (autoscaler.min_replicas if autoscaler is not None
                             else max(1, min_replicas))
        # Everything _make_replica needs to construct a warm-joining replica
        # identical (module, engine geometry) to the boot-time ones.
        self._mk = dict(model=model, params=params, slots=slots,
                        max_len=max_len, decode_batch=decode_batch,
                        page_size=page_size, pool_pages=pool_pages,
                        chunk=chunk, chunks_per_step=chunks_per_step,
                        admit_cap=admit_cap,
                        defrag_threshold=defrag_threshold, extras=extras,
                        draft_model=draft_model if speculative else None,
                        draft_params=draft_params if speculative else None,
                        spec_k=spec_k if speculative else 0)
        self.spec_counters = (self.obs.group(
            "spec", ["admit_spec", "admit_plain", "bursts", "proposed",
                     "accepted", "committed"]) if speculative else None)
        self._svc_kw = dict(seed=seed, budget_s=tuning_budget_s,
                            donor_target=donor_target, donors=donors)

        if targets is None:
            targets = [DEFAULT_TARGET] * replicas
        elif isinstance(targets, str):
            targets = [targets] * replicas
        else:
            targets = [target_name(t) for t in targets]
            if len(targets) != replicas:
                raise ValueError(
                    f"targets ({len(targets)}) must match replicas ({replicas})")

        # One TuningService per distinct target, all over the one registry
        # (created on demand — a warm-join may bring a brand-new target).
        self._services: dict[str, Any] = {}
        self.replicas: list[Replica] = []
        for i, t in enumerate(targets):
            self.replicas.append(self._make_replica(i, t))

        self.demand = DemandTracker(bucket_for=self.replicas[0].bucket_for)
        self.router = RequestRouter(self.replicas, policy=policy,
                                    queue_cap=queue_cap, demand=self.demand,
                                    metrics=self.obs, tracer=self.tracer)
        self.metrics = FleetMetrics(metrics=self.obs)
        #: One untuned decode step of the reference replica — the trace's
        #: time unit (TrafficGenerator ``tick_s``).
        self.tick_s = self.replicas[0].untuned_decode_cost()
        self.prefetched: list[str] = []   # workload keys, in prefetch order
        self._prefetched_seen: set[str] = set()
        #: Lifecycle audit trail: one dict per warm-join / retire.
        self.scale_events: list[dict] = []
        self._events = 0
        self._next_eval: float | None = None
        # Closed-loop observability (DESIGN.md §12): the SLO monitor
        # evaluates burn rates at its own window cadence inside serve()
        # (alerts feed the autoscaler window), the ledger tracks realized
        # vs attainable speedup on the tuning-drain cadence, and the
        # advisor replaces demand-count prefetch ordering when
        # ``prefetch="advisor"``.
        if slos == "default":
            slos = default_slos(self.tick_s)
        elif callable(slos):  # tick-relative spec: thresholds need tick_s
            slos = slos(self.tick_s)
        self.slo_monitor = (SLOMonitor(
            slos, self.metrics, window_s=slo_window_s or 4 * self.tick_s,
            metrics=self.obs, tracer=self.tracer) if slos else None)
        self._slo_next = (self.slo_monitor.window_s
                          if self.slo_monitor is not None else None)
        self.ledger = (SpeedupLedger(metrics=self.obs, tracer=self.tracer)
                       if self._services else None)
        self.advisor = advisor if advisor is not None else (
            TuningAdvisor() if prefetch == "advisor" else None)
        if self.tracer.enabled:
            if self.slo_monitor is not None:
                self.tracer.track(SLOMonitor.TRACK)
            if self.ledger is not None:
                self.tracer.track(SpeedupLedger.TRACK)
            if self.advisor is not None:
                self.tracer.track("advisor")
        if autoscaler is not None:
            self.attach_autoscaler(autoscaler)

    def attach_autoscaler(self, autoscaler) -> None:
        """Attach (or replace) the autoscaler driving :meth:`serve`.

        Callers typically construct the fleet first — :attr:`tick_s` (one
        untuned decode step) is only known then — and size the controller's
        ``window_s``/``cooldown_s`` in ticks of it.
        """
        self.autoscaler = autoscaler
        self.min_replicas = autoscaler.min_replicas
        self._next_eval = self._now + autoscaler.window_s
        bind = getattr(autoscaler, "bind_obs", None)
        if bind is not None:  # controller telemetry joins the fleet's sinks
            bind(self.tracer, self.obs)

    def set_slo_window(self, window_s: float) -> None:
        """Retime the SLO evaluation cadence (call before :meth:`serve`).

        Same rationale as :meth:`attach_autoscaler`: callers size windows in
        ticks of :attr:`tick_s`, which is only known post-construction.
        """
        if self.slo_monitor is None:
            raise ValueError("fleet has no SLO monitor (pass slos=)")
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        self.slo_monitor.window_s = window_s
        self._slo_next = self._now + window_s

    # -- replica construction --------------------------------------------------
    def _service_for(self, target: str):
        """The shared TuningService for ``target`` (created on first use)."""
        if self.registry is None:
            return None
        svc = self._services.get(target)
        if svc is None:
            from repro.service import TuningService  # lazy: optional dep cycle
            svc = self._services[target] = TuningService(
                self.registry, model_id=f"fleet/{self.cfg.name}",
                runner=CachedRunner(AnalyticalRunner(target)),
                max_workers=0, probe_candidates=0, target=target,
                metrics=self.obs, tracer=self.tracer,
                clock=lambda: self._now,
                **self._svc_kw)
        return svc

    def _make_replica(self, idx: int, target: str) -> Replica:
        """Construct one replica (engine + provider) for ``target``.

        The engine builds its :class:`~repro.core.resolution.ExecutionPlan`
        at the *current* registry generation — for a warm-join this is the
        whole point: every shape the fleet already tuned resolves at the
        exact tier before the replica sees its first request.
        """
        mk = self._mk
        svc = self._service_for(target)
        provider = (ScheduleProvider(service=svc) if svc is not None
                    else ScheduleProvider(target=target))
        pipeline = getattr(provider, "pipeline", None)
        if pipeline is not None:
            pipeline.tracer = self.tracer
        if self.engine_kind == "paged":
            eng = PagedServingEngine(
                mk["model"], mk["params"],
                decode_batch=mk["decode_batch"] or mk["slots"],
                max_ctx=mk["max_len"], page_size=mk["page_size"],
                pool_pages=mk["pool_pages"], chunk=mk["chunk"],
                chunks_per_step=mk["chunks_per_step"],
                admit_cap=mk["admit_cap"],
                defrag_threshold=mk["defrag_threshold"],
                draft_model=mk["draft_model"],
                draft_params=mk["draft_params"], spec_k=mk["spec_k"],
                provider=provider)
            self._bind_engine_obs(eng, idx)
            rep = PagedReplica(idx, self.cfg, eng, svc, target)
            if self.spec_mode:
                rep.acceptance = self.acceptance
                rep.spec_counters = self.spec_counters
                rep.spec_gauge = self.obs.gauge("spec.acceptance_rate")
                rep.spec_hist = self.obs.histogram("spec.committed_per_burst")
            return rep
        eng = ServingEngine(mk["model"], mk["params"], slots=mk["slots"],
                            max_len=mk["max_len"], extras=mk["extras"],
                            provider=provider)
        self._bind_engine_obs(eng, idx)
        return Replica(idx, self.cfg, eng, svc, target)

    def _bind_engine_obs(self, eng, idx: int) -> None:
        """Point the engine at the fleet tracer *before* the Replica wrapper
        reads the binding.  Compute spans are disabled: under the virtual
        clock a jitted call is zero-width — the replica emits the
        virtual-time step spans instead."""
        eng.tracer = self.tracer
        eng.trace_track = f"replica-{idx}"
        eng.trace_compute = False

    @property
    def services(self) -> dict:
        """Per-target shared TuningServices (empty without a registry)."""
        return dict(self._services)

    # -- lifecycle views -------------------------------------------------------
    def live_replicas(self) -> list[Replica]:
        """Replicas that still hold or may hold work (active + draining)."""
        return [r for r in self.replicas if r.state != "retired"]

    def active_replicas(self) -> list[Replica]:
        return [r for r in self.replicas if r.state == "active"]

    # -- elastic lifecycle -----------------------------------------------------
    def add_replica(self, target: str | None = None, *,
                    now: float | None = None) -> Replica:
        """Warm-join a new replica and register it with the router.

        The join order is the contract: (1) construct the replica — its
        execution plan resolves at the *current* shared-registry generation,
        so every shape the fleet already tuned is exact-tier from request
        one; (2) prefetch tuning for whatever the demand distribution says
        is hot but still unresolved on this target; (3) only then register
        with the router.  The recorded scale event carries the fleet's
        traffic-weighted exact-tier share just before the join and the new
        replica's share at join, so "warm" is measurable, not asserted.
        """
        now = self._now if now is None else now
        t = target_name(target) if target is not None else self.replicas[0].target
        self.sync_plans()  # compare shares at one registry generation
        pre_share = self._final_exact_share_synced()
        r = self._make_replica(len(self.replicas), t)
        r.joined_s = now
        r.time = now
        if self._services and self.demand.total > 0:
            self._prefetch_uses(r.decode_uses, float(self.demand.total))
            for bucket, count in self.demand.hottest()[:self.prefetch_buckets]:
                self._prefetch_uses(r.prefill_uses(bucket), float(count))
        join_share = (self.demand.weighted(r.prefill_exact_share)
                      if self._services else 0.0)
        self.replicas.append(r)
        self.router.add_replica(r)
        self.scale_events.append({
            "t": now, "action": "join", "replica": r.idx, "target": t,
            "pre_join_exact_share": pre_share,
            "join_exact_share": join_share})
        if self.tracer.enabled:
            self.tracer.track(r.track)
            self.tracer.event("join", "autoscaler", t=now, replica=r.idx,
                              target=t, pre_join_exact_share=pre_share,
                              join_exact_share=join_share)
        return r

    def retire_replica(self, idx: int, *, now: float | None = None) -> Replica:
        """Drain-retire a replica: stop dispatch, finish in-flight work.

        Refused (ValueError) when it would leave fewer than
        ``min_replicas`` active replicas.  Work the engine accepted but has
        not started (the paged engine's waiting queue) is withdrawn and
        requeued at the router front — nothing accepted is ever dropped.
        In-flight requests keep decoding through the normal serve loop; the
        replica finalizes to ``retired`` once empty.
        """
        now = self._now if now is None else now
        r = self.replicas[idx]
        if r.state != "active":
            raise ValueError(f"replica {idx} is {r.state}, not active")
        if len(self.active_replicas()) - 1 < self.min_replicas:
            raise ValueError(
                f"refusing to retire replica {idx}: fleet would drop below "
                f"min_replicas={self.min_replicas}")
        r.state = "draining"
        requeued: list[FleetRequest] = []
        withdraw = getattr(r.engine, "withdraw_waiting", None)
        if withdraw is not None:
            for uid in withdraw():
                fr = r._fleet_reqs.pop(uid, None)
                if fr is not None:
                    fr.replica = None
                    fr.admitted_s = None
                    requeued.append(fr)
            requeued.sort(key=lambda q: q.arrival_s)
            self.router.requeue(requeued)
        self.scale_events.append({
            "t": now, "action": "retire", "replica": idx, "target": r.target,
            "requeued": len(requeued), "in_flight": len(r._fleet_reqs)})
        if self.tracer.enabled:
            self.tracer.event("retire", "autoscaler", t=now, replica=idx,
                              target=r.target, requeued=len(requeued),
                              in_flight=len(r._fleet_reqs))
        if not r.busy and not r.engine.active:
            self._finalize_retire(r, now)
        return r

    def _finalize_retire(self, r: Replica, now: float) -> None:
        r.state = "retired"
        r.retired_s = now
        r.busy = r.step_pending = False
        if self.tracer.enabled:
            self.tracer.event("retired", "autoscaler", t=now, replica=r.idx,
                              target=r.target)
        # Pending tuning jobs for this target are demand the fleet no longer
        # has capacity to exploit — cancel them, but only when no live
        # replica still serves the target (the queue is shared per target).
        svc = self._services.get(r.target)
        if svc is not None and not any(q.target == r.target
                                       for q in self.live_replicas()):
            svc.cancel_pending()

    def _apply_decision(self, decision, now: float) -> None:
        if decision.action == "up":
            self.add_replica(now=now)
        elif decision.action == "down":
            actives = self.active_replicas()
            if len(actives) - 1 < self.min_replicas:
                return  # a drain in progress already took the headroom
            # Victim: fewest in-flight requests (cheapest drain), ties to
            # the youngest replica (keep the fleet's elders warm).
            victim = min(actives, key=lambda r: (len(r._fleet_reqs), -r.idx))
            self.retire_replica(victim.idx, now=now)

    def replica_seconds(self) -> float:
        """Capacity spent: Σ per replica of (retire time − join time), in
        virtual seconds — the equal-cost axis elastic-vs-fixed compares on."""
        end = max(self._now, self.metrics.makespan_s)
        return sum((r.retired_s if r.retired_s is not None else end)
                   - r.joined_s for r in self.replicas)

    # -- demand-driven prefetch ------------------------------------------------
    def _prefetch_uses(self, uses: Sequence[KernelUse], priority: float) -> None:
        for svc in self._services.values():
            db = svc.registry.snapshot().db(None)
            for u in uses:
                if db.exact(u.instance, target=svc.target) is not None:
                    continue
                if svc.prefetch(u.instance, priority=priority):
                    key = u.instance.workload_key()
                    if key not in self._prefetched_seen:
                        self._prefetched_seen.add(key)
                        self.prefetched.append(key)

    def _prefetch_hot(self) -> None:
        """Queue tuning for the hottest unresolved shapes, hottest first.

        The batched decode step is exercised by *every* request, so its
        kernels carry the total demand; after it come the hottest prefill
        buckets by arrival count.  Cold buckets are never touched — their
        jobs stay at the tail of the queue and spend budget only after all
        demanded shapes are tuned.
        """
        total = self.demand.total
        if total == 0:
            return
        self._prefetch_uses(self.replicas[0].decode_uses, float(total))
        for bucket, count in self.demand.hottest()[:self.prefetch_buckets]:
            self._prefetch_uses(self.replicas[0].prefill_uses(bucket),
                                float(count))

    def _prefetch_advised(self) -> None:
        """Advisor-ranked prefetch (``prefetch="advisor"``): queue or
        promote every un-exhausted executed workload at priority
        critical-path-seconds x headroom, so the drain order follows
        end-to-end impact rather than raw arrival counts."""
        ranked = self.advisor.rank(self)
        for rw in ranked:
            svc = self._services.get(rw.target)
            if svc is None or not svc.prefetch(rw.instance,
                                               priority=rw.priority):
                continue
            key = rw.instance.workload_key()
            if key not in self._prefetched_seen:
                self._prefetched_seen.add(key)
                self.prefetched.append(key)
        if ranked and self.tracer.enabled:
            top = ranked[0]
            self.tracer.event(
                "advise", "advisor", t=self._now, candidates=len(ranked),
                top_key=top.instance.workload_key(),
                top_priority=top.priority, top_critical_s=top.critical_s,
                top_headroom=top.headroom)

    def _drain_services(self) -> None:
        for svc in self._services.values():
            svc.drain(max_jobs=self.drain_jobs)

    # -- the serve loop --------------------------------------------------------
    def _complete(self, fr: FleetRequest, now: float) -> None:
        self.metrics.record_completion(fr, now)
        if self.tracer.enabled:
            self._trace_request(fr)

    def _trace_request(self, fr: FleetRequest) -> None:
        """Emit the request's lifecycle as async spans on its replica track.

        Four spans share ``cat="request"`` and ``id=uid`` so Perfetto nests
        them on one async track even when requests overlap: ``request``
        covers arrival→finish, with ``queue``/``prefill``/``decode`` slicing
        it at the admission and first-token instants.  The intervals are the
        exact ones :class:`FleetMetrics` aggregates, so a report computed
        from the trace reproduces the fleet's latency percentiles.
        """
        if fr.admitted_s is None or fr.finished_s is None:
            return
        track = (self.replicas[fr.replica].track if fr.replica is not None
                 else "router")
        uid = str(fr.uid)
        t_arr, t_adm, t_fin = fr.arrival_s, fr.admitted_s, fr.finished_s
        pd = fr.prefill_done_s
        pd = t_adm if pd is None else min(max(pd, t_adm), t_fin)
        add = self.tracer.add_async_span
        add("request", track, t_arr, t_fin, "request", uid, uid=fr.uid,
            bucket=fr.bucket, replica=fr.replica, tokens=fr.tokens,
            latency_s=t_fin - t_arr)
        add("queue", track, t_arr, t_adm, "request", uid, uid=fr.uid)
        add("prefill", track, t_adm, pd, "request", uid, uid=fr.uid)
        add("decode", track, pd, t_fin, "request", uid, uid=fr.uid)

    def _admit(self, req: FleetRequest, idx: int) -> bool:
        replica = self.replicas[idx]
        if self.spec_mode and getattr(replica, "spec_capable", False):
            if self.spec_mode == "auto":
                # Per-request economics: speculate only when the measured
                # per-class acceptance rate projects a throughput win under
                # this replica's plan-derived cell costs.
                alpha = self.acceptance.alpha(req.request_class)
                req.speculative = replica.spec_gain(alpha) > 1.0
            else:
                req.speculative = True
            self.spec_counters.inc(
                "admit_spec" if req.speculative else "admit_plain")
            if self.tracer.enabled:
                self.tracer.event(
                    "spec_route", "router", uid=req.uid,
                    request_class=req.request_class,
                    speculative=req.speculative)
        try:
            engine_req = replica.admit(req, self._now)
        except ValueError:
            # A request the engine can never hold (e.g. prompt > max_len):
            # the router survives it — shed, not crash (False vetoes the
            # placement so it is not counted as dispatched).
            req.shed = "invalid"
            self.metrics.record_shed(req, self._now)
            if self.tracer.enabled:
                self.tracer.event("shed", "router", uid=req.uid,
                                  reason="invalid", replica=idx)
            return False
        if engine_req.done:
            # Finished by the prefill itself (max_new_tokens=0 / prefill
            # EOS): completes when its prefill's virtual time elapses.
            req.tokens = len(engine_req.generated)
            req.generated = list(engine_req.generated)
            self._complete(req, replica.time)
        return True

    def _eligible(self) -> list[int]:
        # Admission happens at step boundaries: a replica mid-(virtual)-step
        # cannot accept work until its clock catches up.  Only *active*
        # replicas take new work — draining ones finish what they hold.
        return [i for i, r in enumerate(self.replicas)
                if r.state == "active" and not r.busy and r.free_slots > 0]

    def serve(self, trace: Sequence[FleetRequest], *,
              max_events: int = 200_000) -> dict:
        """Serve a traffic trace to completion; returns :meth:`summary`."""
        arrivals = sorted(trace, key=lambda r: r.arrival_s)
        ai = 0
        now = 0.0
        while True:
            self._events += 1
            if self._events > max_events:
                raise RuntimeError("fleet serve did not converge")
            next_times = []
            if ai < len(arrivals):
                next_times.append(arrivals[ai].arrival_s)
            busy = [r.time for r in self.replicas if r.busy]
            if busy:
                next_times.append(min(busy))
            if not next_times:
                if not self.router.queue:
                    break
                # Queued work, everything idle: dispatch at the current time.
            else:
                # With an autoscaler (or SLO monitor), window boundaries are
                # events too — the clock never jumps past an evaluation
                # instant.
                if self._next_eval is not None:
                    next_times.append(self._next_eval)
                if self._slo_next is not None:
                    next_times.append(self._slo_next)
                now = max(now, min(next_times))
            self._now = now

            # 1) arrivals up to now enter the admission queue (or shed).
            while ai < len(arrivals) and arrivals[ai].arrival_s <= now:
                req = arrivals[ai]
                ai += 1
                try:
                    self.router.submit(req)
                except QueueFull:
                    self.metrics.record_shed(req, now)

            # 2) work that finishes at now: decode steps run for real.
            for r in self.replicas:
                if r.busy and r.time <= now + 1e-12:
                    if r.step_pending:
                        for fr in r.complete_step(now):
                            self._complete(fr, now)
                    else:
                        r.busy = False  # prefill done; slot batch continues
                if r.state == "draining" and not r.busy \
                        and not r.engine.active:
                    self._finalize_retire(r, now)

            # 3) background tuning in bursts: prefetch ordering (advisor
            #    priority or demand counts), then a bounded drain (publishes
            #    coalesce -> bounded re-plans), then a ledger refresh so the
            #    realized-speedup gauges move the instant publishes land.
            if self._services and self._events % self.drain_every == 0:
                if self.prefetch == "advisor":
                    self._prefetch_advised()
                elif self.prefetch:
                    self._prefetch_hot()
                self._drain_services()
                if self.ledger is not None:
                    self.ledger.update(self.live_replicas(), now=now)

            # 3a) SLO monitor: evaluate burn rates at every window boundary
            #     crossed, *before* the autoscaler folds its window — an
            #     alert raised at a shared boundary is scale-up pressure in
            #     the same instant's decision.
            if self._slo_next is not None:
                while self._slo_next <= now + 1e-12:
                    self.slo_monitor.evaluate(self._slo_next)
                    self._slo_next += self.slo_monitor.window_s

            # 3b) autoscaler: fold the just-closed telemetry window into the
            #     controller and apply its decision *before* dispatch, so a
            #     replica joining now takes requests this same instant.
            if self._next_eval is not None and self.autoscaler is not None:
                while self._next_eval <= now + 1e-12:
                    t1 = self._next_eval
                    w = self.metrics.window(t1 - self.autoscaler.window_s, t1)
                    w["slo_alerts"] = (len(self.slo_monitor.alerting())
                                       if self.slo_monitor is not None else 0)
                    decision = self.autoscaler.observe(
                        w, now=t1, replicas=len(self.live_replicas()))
                    self._apply_decision(decision, t1)
                    self._next_eval += self.autoscaler.window_s

            # 4) route queued requests to replicas at their boundaries.
            self.router.dispatch(now, eligible=self._eligible,
                                 admit=self._admit)
            for fr in self.router.last_shed_deadline:
                self.metrics.record_shed(fr, now)
            live = self.live_replicas()
            self.metrics.sample_queue(self.router.depth, now)
            self.metrics.sample_utilization(
                sum(r.utilization() for r in live) / len(live) if live
                else 0.0, now)
            self.metrics.sample_capacity(
                sum(r.engine.kv_used_tokens() for r in live),
                sum(r.engine.kv_capacity_tokens() for r in live))

            # 5) replicas with active slots begin their next decode step
            #    (draining ones too — that is how they finish their work).
            for r in self.replicas:
                if r.state != "retired" and not r.busy and r.engine.active:
                    r.start_step(now)
        return self.summary()

    # -- cross-replica consistency ---------------------------------------------
    def sync_plans(self) -> None:
        """Bring every replica's plan to the current registry generation
        (the same step-boundary check a live stream would perform; no
        tokens are decoded, so it is safe mid-stream)."""
        for r in self.replicas:
            r.engine.refresh_plan()

    def schedule_mismatches(self) -> int:
        """Byte-level schedule divergence between same-target replicas'
        plans after a sync — shared-registry propagation must make it 0."""
        self.sync_plans()
        return self._schedule_mismatches_synced()

    def _schedule_mismatches_synced(self) -> int:
        groups: dict[str, list[Replica]] = {}
        for r in self.replicas:
            groups.setdefault(r.target, []).append(r)
        mismatches = 0
        for members in groups.values():
            base = members[0].engine.plan
            if base is None:
                continue
            base_bytes = {k: json.dumps(s.to_json(), sort_keys=True)
                          for k, s in base.schedules().items()}
            for other in members[1:]:
                if other.engine.plan is None:
                    continue
                for k, s in other.engine.plan.schedules().items():
                    want = base_bytes.get(k)
                    if want is not None and \
                            json.dumps(s.to_json(), sort_keys=True) != want:
                        mismatches += 1
        return mismatches

    # -- telemetry ------------------------------------------------------------
    def final_exact_share(self) -> float:
        """Traffic-weighted exact-tier share over the demand distribution,
        under the replicas' *current* plans (the end-state quality)."""
        self.sync_plans()
        return self._final_exact_share_synced()

    def _final_exact_share_synced(self) -> float:
        if not self._services:
            return 0.0
        return self.demand.weighted(self.replicas[0].prefill_exact_share)

    def summary(self) -> dict:
        # Padding-waste totals live in the engines (the authoritative
        # ledger); fold them into the metrics before summarizing.
        self.metrics.prefill_true_tokens = sum(
            r.engine.prefill_true_tokens for r in self.replicas)
        self.metrics.prefill_padded_tokens = sum(
            r.engine.prefill_padded_tokens for r in self.replicas)
        out = self.metrics.summary(tick_s=self.tick_s)
        out["engine"] = self.engine_kind
        out["router"] = self.router.stats()
        out["demand"] = self.demand.stats()
        out["replicas"] = [r.stats() for r in self.replicas]
        out["events"] = self._events
        out["prefetched"] = len(self.prefetched)
        out["scale_events"] = list(self.scale_events)
        out["replica_seconds"] = self.replica_seconds()
        if self.spec_mode:
            out["speculative"] = {
                "mode": "auto" if self.spec_mode == "auto" else "all",
                "spec_k": self._mk["spec_k"],
                "counters": dict(self.spec_counters),
                "acceptance": self.acceptance.stats()}
        if self.autoscaler is not None:
            out["autoscaler"] = self.autoscaler.stats()
        self.sync_plans()  # once, for both end-state metrics below
        out["schedule_mismatches"] = self._schedule_mismatches_synced()
        out["final_exact_share"] = self._final_exact_share_synced()
        if self._services:
            out["tuning"] = {t: s.stats() for t, s in self._services.items()}
        if self.slo_monitor is not None:
            out["slo"] = self.slo_monitor.summary()
        if self.ledger is not None:
            # Re-priced after the sync above, so the ledger reflects the
            # end-state plans the other end-state metrics describe.
            self.ledger.update(self.live_replicas(), now=self._now)
            out["speedup_ledger"] = self.ledger.summary()
        return out

    def close(self) -> None:
        """Shut the services down without spending budget on cold shapes:
        queued-but-unstarted background jobs are cancelled, not drained."""
        for svc in self._services.values():
            svc.cancel_pending()
            svc.close()
