"""Fleet metrics: latency percentiles, throughput, queue depth, shed rate.

All times are *virtual seconds* (cost-model kernel time — see DESIGN.md §2);
latencies are also reported in *ticks* (one tick = the untuned decode-step
cost of the reference replica) so numbers are comparable across archs.
"""
from __future__ import annotations

import numpy as np

from repro.fleet.traffic import FleetRequest


def percentile(xs: list[float], q: float) -> float:
    """q-th percentile (0..100, linear interpolation); 0.0 when empty."""
    if not xs:
        return 0.0
    return float(np.percentile(xs, q))


class FleetMetrics:
    """Accumulates per-request outcomes and queue-depth samples."""

    def __init__(self) -> None:
        self.completed: list[FleetRequest] = []
        self.shed: list[FleetRequest] = []
        self.queue_samples: list[int] = []
        self.tokens = 0
        self.makespan_s = 0.0

    def record_completion(self, req: FleetRequest, now: float) -> None:
        req.finished_s = now
        self.completed.append(req)
        self.tokens += req.tokens
        self.makespan_s = max(self.makespan_s, now)

    def record_shed(self, req: FleetRequest) -> None:
        self.shed.append(req)

    def sample_queue(self, depth: int) -> None:
        self.queue_samples.append(depth)

    # -- summary ---------------------------------------------------------------
    def latencies(self) -> list[float]:
        return [r.latency_s for r in self.completed if r.latency_s is not None]

    def summary(self, *, tick_s: float = 1.0) -> dict:
        lats = self.latencies()
        n_done, n_shed = len(self.completed), len(self.shed)
        n_seen = n_done + n_shed
        qs = self.queue_samples
        out = {
            "completed": n_done,
            "shed": n_shed,
            "shed_rate": n_shed / n_seen if n_seen else 0.0,
            "shed_by_reason": {
                reason: sum(1 for r in self.shed if r.shed == reason)
                for reason in sorted({r.shed for r in self.shed})},
            "tokens": self.tokens,
            "makespan_s": self.makespan_s,
            "throughput_tok_per_s": (self.tokens / self.makespan_s
                                     if self.makespan_s > 0 else 0.0),
            "latency_s": {"p50": percentile(lats, 50),
                          "p95": percentile(lats, 95),
                          "p99": percentile(lats, 99)},
            "latency_ticks": {"p50": percentile(lats, 50) / tick_s,
                              "p95": percentile(lats, 95) / tick_s,
                              "p99": percentile(lats, 99) / tick_s},
            "queue_depth_max": max(qs) if qs else 0,
            "queue_depth_mean": sum(qs) / len(qs) if qs else 0.0,
            "exact_share_at_admit_mean": (
                sum(r.exact_share_at_admit for r in self.completed) / n_done
                if n_done else 0.0),
            "tick_s": tick_s,
        }
        return out
