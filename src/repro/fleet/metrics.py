"""Fleet metrics: latency percentiles, throughput, queue depth, shed rate.

All times are *virtual seconds* (cost-model kernel time — see DESIGN.md §2);
latencies are also reported in *ticks* (one tick = the untuned decode-step
cost of the reference replica) so numbers are comparable across archs.

Every number lives in a :class:`repro.obs.MetricsRegistry` under the
``fleet.*`` namespace (counters ``fleet.requests_completed`` /
``fleet.requests_shed`` / ``fleet.tokens``, histogram ``fleet.latency_s``,
gauges ``fleet.queue_depth`` / ``fleet.utilization``), so ``--metrics-out``
exports the same values :meth:`FleetMetrics.summary` prints.  Gauge samples
require their timestamp — an unstamped sample cannot be windowed and used
to silently misfile into the first window.

Beyond the whole-run :meth:`FleetMetrics.summary`, metrics are queryable per
time window: :meth:`FleetMetrics.window` summarizes one ``[t0, t1)`` slice
(completions, sheds, p50/p95, queue depth, replica utilization) and
:meth:`window_summaries` buckets the whole run into ``window_s`` slices
through the same code path — the autoscaler's control signal and the
benchmark's per-phase comparison read the identical numbers.
"""
from __future__ import annotations

from repro.fleet.traffic import FleetRequest
from repro.obs import MetricsRegistry, percentile  # noqa: F401  (re-export)


class FleetMetrics:
    """Accumulates per-request outcomes and timestamped gauge samples."""

    def __init__(self, metrics: MetricsRegistry | None = None) -> None:
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.completed: list[FleetRequest] = []
        self.shed: list[FleetRequest] = []
        self._completed_c = self.metrics.counter("fleet.requests_completed")
        self._shed_c = self.metrics.counter("fleet.requests_shed")
        self._tokens_c = self.metrics.counter("fleet.tokens")
        self._latency_h = self.metrics.histogram("fleet.latency_s")
        self._queue_g = self.metrics.gauge("fleet.queue_depth")
        self._util_g = self.metrics.gauge("fleet.utilization")
        self.makespan_s = 0.0
        # padding-waste ledger: prompt tokens the engines actually needed vs
        # tokens they computed (slot-engine prefill buckets pad; the paged
        # engine's chunked prefill holds the two equal)
        self.prefill_true_tokens = 0
        self.prefill_padded_tokens = 0
        # KV capacity samples: (rows holding tokens, rows reserved) per
        # observation — stranded capacity is the gap between the two
        self.capacity_samples: list[tuple[int, int]] = []

    @property
    def tokens(self) -> int:
        return int(self._tokens_c.value)

    @property
    def queue_samples(self) -> list[tuple[float, float]]:
        return self._queue_g.samples

    @property
    def util_samples(self) -> list[tuple[float, float]]:
        return self._util_g.samples

    def record_completion(self, req: FleetRequest, now: float) -> None:
        req.finished_s = now
        self.completed.append(req)
        self._completed_c.inc()
        self._tokens_c.inc(req.tokens)
        if req.latency_s is not None:
            self._latency_h.observe(req.latency_s)
        self.makespan_s = max(self.makespan_s, now)

    def record_shed(self, req: FleetRequest, now: float | None = None) -> None:
        req.shed_s = now if now is not None else req.arrival_s
        self.shed.append(req)
        self._shed_c.inc()

    def sample_queue(self, depth: int, now: float) -> None:
        self._queue_g.sample(depth, now)

    def sample_utilization(self, util: float, now: float) -> None:
        """Sample mean replica utilization (0..1) at an event point."""
        self._util_g.sample(util, now)

    def record_padding(self, true_tokens: int, padded_tokens: int) -> None:
        """Account one prefill: tokens the prompt needed vs tokens computed."""
        self.prefill_true_tokens += true_tokens
        self.prefill_padded_tokens += padded_tokens

    def sample_capacity(self, used_tokens: int, capacity_tokens: int) -> None:
        """Sample KV occupancy (summed across replicas) at an event point."""
        self.capacity_samples.append((used_tokens, capacity_tokens))

    # -- windowed views --------------------------------------------------------
    def window(self, t0: float, t1: float) -> dict:
        """Summary of the ``[t0, t1)`` slice — the autoscaler's signal.

        Completions are binned by finish time, sheds by shed time, queue and
        utilization samples by sample time.  The same dict shape is used by
        :meth:`window_summaries`, so a controller tuned against bench windows
        sees the identical signal live.
        """
        done = [r for r in self.completed
                if r.finished_s is not None and t0 <= r.finished_s < t1]
        shed = [r for r in self.shed
                if r.shed_s is not None and t0 <= r.shed_s < t1]
        lats = [r.latency_s for r in done if r.latency_s is not None]
        qs = self._queue_g.values(t0, t1)
        us = self._util_g.values(t0, t1)
        n_seen = len(done) + len(shed)
        return {
            "t0": t0,
            "t1": t1,
            "completed": len(done),
            "shed": len(shed),
            "shed_rate": len(shed) / n_seen if n_seen else 0.0,
            "tokens": sum(r.tokens for r in done),
            "latency_s": {"p50": percentile(lats, 50),
                          "p95": percentile(lats, 95),
                          "p99": percentile(lats, 99)},
            "queue_depth_mean": sum(qs) / len(qs) if qs else 0.0,
            "queue_depth_max": max(qs) if qs else 0,
            "utilization_mean": sum(us) / len(us) if us else 0.0,
        }

    def window_summaries(self, window_s: float, *,
                         until: float | None = None) -> list[dict]:
        """Bucket the run into ``window_s`` slices (per-phase comparisons)."""
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        end = until if until is not None else self.makespan_s
        out = []
        t0 = 0.0
        while t0 < end or not out:
            out.append(self.window(t0, t0 + window_s))
            t0 += window_s
        return out

    # -- summary ---------------------------------------------------------------
    def latencies(self) -> list[float]:
        return [r.latency_s for r in self.completed if r.latency_s is not None]

    def summary(self, *, tick_s: float = 1.0) -> dict:
        lats = self.latencies()
        n_done, n_shed = len(self.completed), len(self.shed)
        n_seen = n_done + n_shed
        qs = [d for _, d in self.queue_samples]
        out = {
            "completed": n_done,
            "shed": n_shed,
            "shed_rate": n_shed / n_seen if n_seen else 0.0,
            "shed_by_reason": {
                reason: sum(1 for r in self.shed if r.shed == reason)
                for reason in sorted({r.shed for r in self.shed})},
            "tokens": self.tokens,
            "makespan_s": self.makespan_s,
            "throughput_tok_per_s": (self.tokens / self.makespan_s
                                     if self.makespan_s > 0 else 0.0),
            "latency_s": {"p50": percentile(lats, 50),
                          "p95": percentile(lats, 95),
                          "p99": percentile(lats, 99)},
            "latency_ticks": {"p50": percentile(lats, 50) / tick_s,
                              "p95": percentile(lats, 95) / tick_s,
                              "p99": percentile(lats, 99) / tick_s},
            "queue_depth_max": max(qs) if qs else 0,
            "queue_depth_mean": sum(qs) / len(qs) if qs else 0.0,
            # fraction of prefill compute spent on pad tokens (0.0 for the
            # paged engine — chunked prefill never pads)
            "padding_waste_frac": (
                1.0 - self.prefill_true_tokens / self.prefill_padded_tokens
                if self.prefill_padded_tokens else 0.0),
            "kv_utilization_mean": (
                sum(u / c for u, c in self.capacity_samples if c)
                / len(self.capacity_samples) if self.capacity_samples else 0.0),
            # reserved-but-empty KV rows, averaged over samples: capacity the
            # fixed-slot layout strands that a paged pool can re-admit into
            "stranded_capacity_frac": (
                sum(1.0 - u / c for u, c in self.capacity_samples if c)
                / len(self.capacity_samples) if self.capacity_samples else 0.0),
            "exact_share_at_admit_mean": (
                sum(r.exact_share_at_admit for r in self.completed) / n_done
                if n_done else 0.0),
            "tick_s": tick_s,
        }
        return out
