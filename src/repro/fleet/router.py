"""Request router: bounded admission queue + pluggable dispatch policies.

The router is the fleet's front door.  Requests are **submitted** into a
bounded FIFO queue (overflow raises :class:`QueueFull` — the backpressure
signal callers shed on) and **dispatched** to engine replicas at their step
boundaries by a :class:`DispatchPolicy`:

* ``round_robin``   — cycle replicas, skipping ones with no free slot;
* ``least_loaded``  — the replica with the most free decode slots;
* ``plan_aware``    — the replica whose :class:`~repro.core.resolution.\
ExecutionPlan` resolves the request's prefill bucket at the best tier
  (exact > transfer > static > default), ties broken by free slots — route
  work to the replica already holding the best schedules for its shape.
  When a request carries a deadline and replicas expose ``expected_step_s``
  (the paged replicas do), replicas whose projected completion time fits the
  deadline outrank those whose does not, *before* tier quality is compared —
  a fast-enough replica with a weaker plan beats a slow one with exact
  schedules that would blow the deadline anyway.

Requests whose deadline passed while queued are shed at dispatch time
(``shed_deadline``); every arrival is recorded into the optional
:class:`~repro.fleet.demand.DemandTracker` (even shed ones — sheds are
demand too, and exactly the shapes worth tuning for).

Policies see replicas through a tiny surface: ``free_slots`` (property) and
``prefill_tier_score(prompt_len)`` — both the real fleet replica wrapper and
test fakes implement it.  ``register_policy`` adds new policies without
touching the router.
"""
from __future__ import annotations

import collections
from typing import Callable, Sequence

from repro.core.resolution import TIERS
from repro.fleet.traffic import FleetRequest
from repro.obs import NULL_TRACER, MetricsRegistry

#: Tier quality used by plan-aware routing: strongest tier scores highest
#: (exact=3 .. default=0), derived from the resolution pipeline's order.
TIER_SCORE = {t: float(i) for i, t in enumerate(reversed(TIERS))}


class QueueFull(RuntimeError):
    """Admission queue at capacity: the router's backpressure signal."""


class DispatchPolicy:
    """Choose a replica index for a request (None: no eligible replica).

    ``eligible`` is the subset of replica indices the fleet allows right now
    (at a step boundary with a free slot); policies must pick from it.
    """

    name = "policy"

    def select(self, req: FleetRequest, replicas: Sequence,
               eligible: Sequence[int], *, now: float = 0.0) -> int | None:
        raise NotImplementedError


class RoundRobin(DispatchPolicy):
    """Cycle replica indices, skipping ineligible ones."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def select(self, req, replicas, eligible, *, now=0.0):
        if not eligible:
            return None
        pool = set(eligible)
        n = len(replicas)
        for i in range(n):
            idx = (self._next + i) % n
            if idx in pool:
                self._next = (idx + 1) % n
                return idx
        return None


class LeastLoaded(DispatchPolicy):
    """The eligible replica with the most free decode slots."""

    name = "least_loaded"

    def select(self, req, replicas, eligible, *, now=0.0):
        if not eligible:
            return None
        return max(eligible, key=lambda i: (replicas[i].free_slots, -i))


class PlanAware(DispatchPolicy):
    """Prefer the replica whose plan resolves this prompt's prefill bucket
    at the best tier; free slots break ties (then lowest index).

    Deadline fit is the leading key: for a request with ``deadline_s``, a
    replica exposing ``expected_step_s`` (its cost-model estimate for the
    next iteration) is projected forward ``max_new_tokens`` steps from
    ``now`` — replicas that land inside the deadline sort ahead of those
    that do not.  Replicas without the gauge (the slot engine, test fakes)
    are treated as fitting, which degrades to the pre-deadline ordering.

    Speculating replicas commit more than one token per iteration, so a
    per-step projection over-estimates them; when a replica exposes a
    per-class ``expected_token_s(request_class)`` (non-None — the paged
    replica returns one exactly when speculation is on), the projection
    uses the request's class-specific seconds-per-committed-token instead.
    """

    name = "plan_aware"

    @staticmethod
    def _fits(req, replica, now: float) -> float:
        if req.deadline_s is None:
            return 1.0
        horizon = max(1, getattr(req, "max_new_tokens", 1))
        tok_s = getattr(replica, "expected_token_s", None)
        if tok_s is not None:
            per_tok = tok_s(getattr(req, "request_class", ""))
            if per_tok is not None:
                return 1.0 if now + per_tok * horizon <= req.deadline_s else 0.0
        step_s = getattr(replica, "expected_step_s", None)
        if step_s is None:
            return 1.0
        step_s = step_s() if callable(step_s) else step_s
        return 1.0 if now + step_s * horizon <= req.deadline_s else 0.0

    def select(self, req, replicas, eligible, *, now=0.0):
        if not eligible:
            return None
        return max(eligible,
                   key=lambda i: (self._fits(req, replicas[i], now),
                                  replicas[i].prefill_tier_score(len(req.prompt)),
                                  replicas[i].free_slots, -i))


POLICIES: dict[str, type[DispatchPolicy]] = {}


def register_policy(cls: type[DispatchPolicy]) -> type[DispatchPolicy]:
    """Register a policy class under its ``name`` (also usable as a
    decorator for out-of-tree policies)."""
    POLICIES[cls.name] = cls
    return cls


for _cls in (RoundRobin, LeastLoaded, PlanAware):
    register_policy(_cls)


def make_policy(policy: "str | DispatchPolicy") -> DispatchPolicy:
    """Resolve a policy name to a fresh instance (policies are stateful)."""
    if isinstance(policy, DispatchPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise KeyError(f"unknown dispatch policy {policy!r}; "
                       f"registered: {sorted(POLICIES)}") from None


class RequestRouter:
    """Bounded admission queue in front of N replicas.

    ``submit`` enqueues (raising :class:`QueueFull` at ``queue_cap``) and
    records demand; ``dispatch`` drains the queue head-first through the
    policy until no replica is eligible, shedding deadline-expired requests
    as it goes.  The router never touches engines directly — the ``admit``
    callback (the fleet) performs the actual admission, so the router stays
    testable with fake replicas.
    """

    def __init__(self, replicas: Sequence, *,
                 policy: "str | DispatchPolicy" = "round_robin",
                 queue_cap: int = 64, demand=None,
                 metrics: MetricsRegistry | None = None, tracer=None):
        if queue_cap <= 0:
            raise ValueError("queue_cap must be positive")
        self.replicas = list(replicas)
        self.requeued = 0
        self.policy = make_policy(policy)
        self.queue: collections.deque[FleetRequest] = collections.deque()
        self.queue_cap = queue_cap
        self.demand = demand
        self.max_queue_depth = 0
        #: Requests shed for a passed deadline during the latest dispatch
        #: (callers fold them into their metrics after each call).
        self.last_shed_deadline: list[FleetRequest] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.counters = self.metrics.group(
            "router", ["submitted", "shed_queue_full", "shed_deadline",
                       "dispatched"])

    @property
    def depth(self) -> int:
        return len(self.queue)

    # -- dynamic replica set ---------------------------------------------------
    def add_replica(self, replica) -> int:
        """Register a new replica; returns its (stable) index.

        Indices are positional and never reused — a retired replica keeps
        its slot in the list and is excluded from dispatch by the
        ``dispatchable`` flag, so policies and in-flight requests holding an
        index stay valid across the fleet's whole lifetime.
        """
        self.replicas.append(replica)
        return len(self.replicas) - 1

    def requeue(self, reqs: Sequence[FleetRequest]) -> None:
        """Return already-admitted requests to the *front* of the queue.

        Used by drain-retire: requests a draining replica had queued but
        never started go back ahead of new arrivals and are exempt from
        ``queue_cap`` — they were admitted once, so bouncing them now would
        silently drop accepted work.
        """
        for req in reversed(list(reqs)):
            self.queue.appendleft(req)
            self.requeued += 1
        self.max_queue_depth = max(self.max_queue_depth, len(self.queue))

    # -- admission -------------------------------------------------------------
    def submit(self, req: FleetRequest) -> None:
        """Enqueue a request; raises :class:`QueueFull` at capacity.

        Demand is recorded for *every* arrival, shed or not: a shed request
        is still evidence its shape is hot.  Deadlines are enforced at
        :meth:`dispatch` time, not here — an expired request still leaves
        the queue through the shed path so it is accounted exactly once.
        """
        self.counters["submitted"] += 1
        if self.demand is not None:
            self.demand.record(req)
        if len(self.queue) >= self.queue_cap:
            req.shed = "queue_full"
            self.counters["shed_queue_full"] += 1
            if self.tracer.enabled:
                self.tracer.event("shed", "router", uid=req.uid,
                                  reason="queue_full", depth=len(self.queue))
            raise QueueFull(
                f"admission queue at capacity ({self.queue_cap})")
        self.queue.append(req)
        self.max_queue_depth = max(self.max_queue_depth, len(self.queue))
        if self.tracer.enabled:
            self.tracer.event("submit", "router", uid=req.uid,
                              depth=len(self.queue))

    # -- dispatch --------------------------------------------------------------
    def dispatch(self, now: float = 0.0, *,
                 eligible: "Callable[[], Sequence[int]] | Sequence[int] | None" = None,
                 admit: "Callable[[FleetRequest, int], None] | None" = None,
                 ) -> list[tuple[FleetRequest, int]]:
        """Assign queued requests to replicas until the policy finds none.

        ``eligible`` is a callable re-evaluated per assignment (admission
        changes slot occupancy), a static index list, or None (any replica
        with a free slot).  ``admit(req, idx)`` performs the admission;
        without one, ``replicas[idx].admit(req, now)`` is called.  An admit
        that returns ``False`` vetoed the placement (e.g. the engine shed
        the request as invalid) — the request counts as neither queued nor
        dispatched.  Returns the (request, replica index) assignments made.
        """
        shed_deadline: list[FleetRequest] = []
        out: list[tuple[FleetRequest, int]] = []
        while self.queue:
            req = self.queue[0]
            if req.deadline_s is not None and now > req.deadline_s:
                self.queue.popleft()
                req.shed = "deadline"
                self.counters["shed_deadline"] += 1
                if self.tracer.enabled:
                    self.tracer.event("shed", "router", t=now, uid=req.uid,
                                      reason="deadline")
                shed_deadline.append(req)
                continue
            if callable(eligible):
                elig = [i for i in eligible()
                        if self.replicas[i].free_slots > 0]
            elif eligible is not None:
                elig = [i for i in eligible if self.replicas[i].free_slots > 0]
            else:
                # Draining/retired replicas advertise dispatchable=False and
                # never receive new work (fakes without the flag all do).
                elig = [i for i, r in enumerate(self.replicas)
                        if r.free_slots > 0
                        and getattr(r, "dispatchable", True)]
            idx = self.policy.select(req, self.replicas, elig, now=now)
            if idx is None:
                break
            self.queue.popleft()
            if admit is not None:
                placed = admit(req, idx)
            else:
                placed = self.replicas[idx].admit(req, now)
            if placed is False:
                continue
            self.counters["dispatched"] += 1
            if self.tracer.enabled:
                self.tracer.event("dispatch", "router", t=now, uid=req.uid,
                                  replica=idx, policy=self.policy.name)
            out.append((req, idx))
        self.last_shed_deadline = shed_deadline
        return out

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> dict:
        out = dict(self.counters)
        out["policy"] = self.policy.name
        out["queue_depth"] = self.depth
        out["queue_cap"] = self.queue_cap
        out["max_queue_depth"] = self.max_queue_depth
        out["requeued"] = self.requeued
        return out
