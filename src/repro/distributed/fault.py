"""Fault tolerance: straggler detection, preemption handling, elastic re-mesh.

* :class:`StragglerMonitor` — EWMA of per-step wall times; steps slower than
  ``threshold×`` the EWMA are flagged (on a real fleet this feeds the
  controller that triggers hot-spare swaps; here it also powers tests and
  the train-loop log).
* :class:`PreemptionHandler` — converts SIGTERM (and a programmatic
  ``request()``) into a "checkpoint now, then exit cleanly" flag the train
  loop polls each step.
* :func:`elastic_restore` — restore a checkpoint onto a *different* mesh
  (fewer/more devices): rebuilds shardings for the new mesh and device_puts
  every leaf accordingly (checkpoints store full logical arrays, so this is
  total — scale 512→256 or down to the 8-device test mesh).
"""
from __future__ import annotations

import signal
import threading
import time
from typing import Any

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd


class StragglerMonitor:
    def __init__(self, alpha: float = 0.2, threshold: float = 2.0, warmup: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: float | None = None
        self.count = 0
        self.flagged: list[tuple[int, float, float]] = []  # (step, dt, ewma)

    def record(self, step: int, dt: float) -> bool:
        """Record one step duration; returns True if flagged as straggler."""
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = self.count > self.warmup and dt > self.threshold * self.ewma
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
        else:
            # stragglers don't poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class PreemptionHandler:
    """SIGTERM → graceful 'checkpoint and exit' request."""

    def __init__(self, install_signal: bool = True):
        self._event = threading.Event()
        if install_signal:
            try:
                signal.signal(signal.SIGTERM, lambda *_: self._event.set())
            except ValueError:
                pass  # non-main thread (tests)

    def request(self) -> None:
        self._event.set()

    @property
    def requested(self) -> bool:
        return self._event.is_set()


def elastic_restore(manager: CheckpointManager, template: Any, cfg: ArchConfig,
                    new_mesh, step: int | None = None) -> tuple[int, Any]:
    """Restore (params, opt_state, ...) bundle onto `new_mesh`.

    `template` must be the abstract bundle {"params":…, "opt":…}; shardings
    are rebuilt for the new mesh from the same logical rules, so any
    divisibility fallbacks re-evaluate for the new axis sizes.
    """
    p_shard = shd.param_shardings(template["params"], cfg, new_mesh)
    shardings = {"params": p_shard}
    if "opt" in template:
        shardings["opt"] = shd.opt_state_shardings(p_shard, new_mesh)
    full = dict(template)
    return manager.restore(full, step=step, shardings=_pad_tree(shardings, full))


def _pad_tree(shardings: dict, template: dict) -> dict:
    """Extend the sharding tree with None for any extra template keys."""
    out = {}
    for k, v in template.items():
        if k in shardings:
            out[k] = shardings[k]
        else:
            out[k] = jax.tree_util.tree_map(lambda _: None, v)
    return out
