from repro.distributed.fault import PreemptionHandler, StragglerMonitor, elastic_restore
from repro.distributed.pipeline import bubble_fraction, pipeline_apply
from repro.distributed.sharding import (
    activation_sharding,
    batch_shardings,
    fsdp_axes,
    logits_sharding,
    moe_expert_parallel,
    opt_state_shardings,
    param_shardings,
    param_spec,
)

__all__ = [
    "PreemptionHandler",
    "StragglerMonitor",
    "activation_sharding",
    "batch_shardings",
    "bubble_fraction",
    "elastic_restore",
    "fsdp_axes",
    "logits_sharding",
    "moe_expert_parallel",
    "opt_state_shardings",
    "param_shardings",
    "param_spec",
    "pipeline_apply",
]
