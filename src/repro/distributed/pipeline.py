"""Pipeline parallelism over the ``pod`` axis (GPipe via shard_map).

For deployments where cross-pod bandwidth makes pure DP over pods
unattractive, layer groups can instead be placed per pod and microbatches
streamed through with ``jax.lax.ppermute`` boundary transfers.

``pipeline_apply`` is self-contained: it takes a per-stage ``stage_fn`` and
stage-stacked params, splits the batch into microbatches, and runs the
classic GPipe schedule (n_micro + n_stages - 1 ticks).  Each device holds
one stage; at every tick it applies its stage to its current microbatch and
ppermutes activations to the next stage.  Bubble fraction =
(S-1)/(M+S-1), reported by :func:`bubble_fraction` so launch configs can
size microbatch counts.

Tested under a subprocess with 8 host devices (tests/test_distributed.py);
selectable in the launcher via ``--pipeline``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if hasattr(jax, "shard_map"):                     # jax >= 0.6
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:                                             # jax 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    _shard_map = functools.partial(_experimental_shard_map, check_rep=False)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # pytree with leading dim = n_stages
    x: jax.Array,               # (batch, ...) global batch
    *,
    mesh: Mesh,
    axis: str = "pod",
    n_microbatches: int | None = None,
) -> jax.Array:
    """Run x through n_stages sequential stages, one stage per `axis` shard."""
    n_stages = mesh.shape[axis]
    n_micro = n_microbatches or n_stages
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    micro = x.reshape(n_micro, mb, *x.shape[1:])
    ticks = n_micro + n_stages - 1

    def per_stage(params_stage, micro_all):
        """Runs on ONE device (stage s). micro_all: all microbatches (only
        stage 0 consumes them; others receive via ppermute)."""
        s = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(micro_all[0])  # current activation
        outs = jnp.zeros_like(micro_all)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range)
            inject = micro_all[jnp.clip(t, 0, n_micro - 1)]
            buf = jnp.where((s == 0) & (t < n_micro), inject, buf)
            # active if this stage holds microbatch (t - s) in [0, n_micro)
            active = (t >= s) & (t - s < n_micro)
            y = stage_fn(params_stage, buf)
            buf_out = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            done_idx = jnp.clip(t - s, 0, n_micro - 1)
            record = (s == n_stages - 1) & active
            outs = jnp.where(
                record,
                outs.at[done_idx].set(buf_out),
                outs,
            )
            # forward activations to next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(buf_out, axis, perm)
            return buf_next, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage recorded real outputs; make the replicated
        # out_spec well-defined by summing across stages (others hold zeros)
        return jax.lax.psum(outs, axis)

    in_specs = (P(axis), P())          # params: stage-sharded; micro: replicated
    out_specs = P()                    # outputs gathered (replicated) per stage
    fn = _shard_map(
        lambda p, m: per_stage(jax.tree_util.tree_map(lambda l: l[0], p), m),
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )
    outs = fn(stage_params, micro)
    return outs.reshape(b, *x.shape[1:])
