"""Logical-axis sharding rules: DP + FSDP (ZeRO-3) + TP/EP + activation SP.

Mesh axes:
  ``pod``    (multi-pod only) — outer data parallelism across pods,
  ``data``   — data parallelism + FSDP param/optimizer sharding,
  ``model``  — tensor / expert parallelism.

Rules are *path-based* over the param pytree (the framework owns the
naming), with divisibility-aware fallbacks:
  * expert dims shard over ``model`` when n_experts % model_size == 0
    (dbrx 16e on 16) else experts replicate and d_ff takes ``model``
    (mixtral 8e on 16 → TP inside experts);
  * head dims shard over ``model`` when divisible, else replicate
    (GSPMD would pad; we prefer explicit replication for KV heads).

Activations: the residual stream is constrained to
P((pod,data), None, model) — embedding-dim sharded layer boundaries
(Megatron-SP analogue) so 40-layer remat checkpoints fit HBM; XLA inserts
the all-gather/reduce-scatter pairs around attention/MLP blocks.

Optimizer state (m/v/master) reuses the param rule leaf-for-leaf.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_dominant(cfg: ArchConfig, mesh: Mesh, *, kind: str, global_batch: int) -> bool:
    """Pure-DP/ZeRO-3 strategy gate (§Perf iteration 7): small models whose
    fully-sharded state fits pay far less in weight gathers (≈4·params
    bytes/step) than tensor parallelism pays in activation reductions
    (≈4·layers·B_local·S·D bytes/step).  Applied when the whole batch
    divides the chip count and params+optimizer ≤ ~25% HBM when
    fully sharded."""
    import math as _math

    chips = _math.prod(tuple(mesh.shape.values()))
    if kind != "train" or global_batch % chips:
        return False
    return cfg.param_count() <= 3.5e9


def _axis_size(mesh: Mesh, axes: tuple[str, ...] | str | None) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh: Mesh, axes, dim: int):
    """Use the axes only if they divide the dim; else replicate."""
    return axes if dim % _axis_size(mesh, axes) == 0 else None


def moe_expert_parallel(cfg: ArchConfig, mesh: Mesh) -> bool:
    return cfg.n_experts > 0 and cfg.n_experts % mesh.shape["model"] == 0


#: leaf-name -> logical axis layout. "fsdp" / "tp" / None per dimension,
#: matched against the *trailing* dims of the leaf (stacked scan groups add
#: a leading repeat dim that stays unsharded).
_LEAF_RULES: dict[str, tuple] = {
    # embeddings / head
    "embed": ("tp", "fsdp"),            # (V, D)
    "lm_head": ("fsdp", "tp"),          # (D, V)
    "dec_pos": (None, "fsdp"),
    "enc_pos": (None, "fsdp"),
    "vis_proj": ("fsdp", "tp"),
    # attention projections
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # dense MLP (gated or plain)
    "w_in": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    "b_in": ("tp",),
    # MoE (expert-parallel layout; TP fallback applied below)
    "router": ("fsdp", None),
    # rwkv
    "wg": ("fsdp", "tp"),
    "wr": ("fsdp", "tp"),
    "wa": ("fsdp", None),
    "wb": (None, "tp"),
    "ck": ("fsdp", "tp"),
    "cv": ("tp", "fsdp"),
    "cr": ("fsdp", "tp"),
    "u": ("tp", None),                  # (H, hd)
    # griffin
    "w_gate": ("fsdp", "tp"),
    "w_x": ("fsdp", "tp"),
    "conv": (None, "tp"),
    "lambda": ("tp",),
    "gate_a": ("tp",),
    "gate_i": ("tp",),
}


def _leaf_name(path: str) -> str:
    # keystr like "['groups']['0']['attn']['wq']" -> "wq"
    return path.rstrip("]'").rsplit("'", 1)[-1] if "'" in path else path


def param_spec(path: str, shape: tuple[int, ...], cfg: ArchConfig, mesh: Mesh,
               dp_only: bool = False) -> P:
    """PartitionSpec for one param leaf, by its tree path.

    dp_only: pure-DP/ZeRO-3 strategy — the "fsdp" logical axis covers the
    whole mesh and "tp" dims replicate (weights gathered, no TP collectives).
    """
    fsdp = all_axes(mesh) if dp_only else fsdp_axes(mesh)
    tp_phys = None if dp_only else "model"
    name = _leaf_name(path)

    def resolve(layout: tuple) -> P:
        # align layout to the trailing dims; leading (stack) dims unsharded
        pad = len(shape) - len(layout)
        full = (None,) * pad + layout
        axes = []
        for a, d in zip(full, shape):
            phys = fsdp if a == "fsdp" else (tp_phys if a == "tp" else None)
            axes.append(_maybe(mesh, phys, d))
        return P(*axes)

    # MoE expert weights: (E, D, 2F)/(E, F, D) — EP when E divides the axis.
    if name in ("w_in", "w_out") and len(shape) >= 3 and cfg.n_experts > 0 and shape[-3] == cfg.n_experts:
        if moe_expert_parallel(cfg, mesh):
            layout = ("tp", "fsdp", None) if name == "w_in" else ("tp", None, "fsdp")
        else:
            layout = (None, "fsdp", "tp") if name == "w_in" else (None, "tp", "fsdp")
        return resolve(layout)
    if name in _LEAF_RULES:
        return resolve(_LEAF_RULES[name])
    return P(*([None] * len(shape)))


def param_shardings(abstract_params: Any, cfg: ArchConfig, mesh: Mesh,
                    dp_only: bool = False) -> Any:
    """NamedSharding tree matching the (abstract) param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        out.append(NamedSharding(mesh, param_spec(path, leaf.shape, cfg, mesh, dp_only)))
    return treedef.unflatten(out)


def opt_state_shardings(param_shardings_tree: Any, mesh: Mesh) -> dict:
    """Optimizer state inherits param shardings (m/v/master); step replicated."""
    return {
        "m": param_shardings_tree,
        "v": param_shardings_tree,
        "master": param_shardings_tree,
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------
# Batch / activation / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(specs: dict, cfg: ArchConfig, mesh: Mesh,
                    dp_only: bool = False) -> dict:
    """Shardings for input_specs trees (train/prefill/decode)."""
    fsdp = all_axes(mesh) if dp_only else fsdp_axes(mesh)

    def leaf_spec(path: str, leaf) -> NamedSharding:
        shape = leaf.shape
        if path.endswith("['tokens']") or "tokens" in path:
            if len(shape) == 1:  # decode: (B,)
                return NamedSharding(mesh, P(_maybe(mesh, fsdp, shape[0])))
            b, s = shape
            if b % _axis_size(mesh, fsdp) == 0:
                return NamedSharding(mesh, P(fsdp, None))
            return NamedSharding(mesh, P(None, _maybe(mesh, fsdp, s)))
        if "mask" in path:
            b = shape[0]
            return NamedSharding(mesh, P(_maybe(mesh, fsdp, b), None))
        if "frames" in path or "patch_embeds" in path:
            return NamedSharding(mesh, P(_maybe(mesh, fsdp, shape[0]), None, None))
        # cache leaves
        return _cache_leaf_sharding(path, shape, cfg, mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    return treedef.unflatten([
        leaf_spec(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat
    ])


def _cache_leaf_sharding(path: str, shape: tuple[int, ...], cfg: ArchConfig,
                         mesh: Mesh) -> NamedSharding:
    fsdp = fsdp_axes(mesh)
    nd = len(shape)
    if nd == 0:  # step counter
        return NamedSharding(mesh, P())
    # stacked-layer leading dim? KV caches inside scan groups have shape
    # (reps, B, H, S, D); recurrent states (reps, B, ...); detect by name.
    has_rep = "groups" in path or "layers" in path
    spec: list = [None] * nd
    i = 1 if has_rep else 0
    if nd - i >= 1 and shape[i] % _axis_size(mesh, fsdp) == 0 and shape[i] > 1:
        spec[i] = fsdp                 # batch dim
        batch_sharded = True
    else:
        batch_sharded = False
    if "['k']" in path or "['v']" in path or "cross_k" in path or "cross_v" in path:
        # (B, Hkv, S, hd): heads over model if divisible; else shard head_dim
        # (GQA kv-head counts are often below the TP degree — leaving the
        # cache replicated costs an all-gather of the whole cache per step,
        # measured ~100 GiB on stablelm decode_32k; see EXPERIMENTS §Perf).
        if shape[i + 1] % mesh.shape["model"] == 0:
            spec[i + 1] = "model"
        elif shape[i + 3] % mesh.shape["model"] == 0:
            spec[i + 3] = "model"
        if not batch_sharded and shape[i + 2] % _axis_size(mesh, fsdp) == 0:
            spec[i + 2] = fsdp
    elif "state" in path or "['h']" in path:
        # recurrent states: shard the big channel/head dim over model
        for j in range(i + 1, nd):
            if shape[j] % mesh.shape["model"] == 0 and shape[j] >= mesh.shape["model"]:
                spec[j] = "model"
                break
    elif "conv" in path or "last_" in path:
        if shape[-1] % mesh.shape["model"] == 0:
            spec[-1] = "model"
    return NamedSharding(mesh, P(*spec))


def activation_sharding(mesh: Mesh, cfg: ArchConfig,
                        dp_only: bool = False,
                        seq_parallel: bool = False) -> NamedSharding:
    """Residual-stream constraint (B, S, D): batch over fsdp, D over model
    (pure-DP strategy: batch over the whole mesh, D replicated;
    seq_parallel: S over model — context parallelism for prefill, §Perf it-8)."""
    if dp_only:
        return NamedSharding(mesh, P(all_axes(mesh), None, None))
    fsdp = fsdp_axes(mesh)
    if seq_parallel:
        return NamedSharding(mesh, P(fsdp, "model", None))
    d_ok = cfg.d_model % mesh.shape["model"] == 0
    return NamedSharding(mesh, P(fsdp, None, "model" if d_ok else None))


def internal_sharding_rules(mesh: Mesh, cfg: ArchConfig) -> dict:
    """Named constraints for internal activations (context.set_sharding_rules).

    moe_buf (E, cap, D): expert-parallel archs shard experts over `model`
    (tokens all-to-all to their experts' shards); TP-fallback archs shard
    capacity over the fsdp axes so the dispatch scatter stays data-local.
    """
    rules: dict = {}
    if cfg.n_experts > 0:
        fsdp = fsdp_axes(mesh)
        if not moe_expert_parallel(cfg, mesh):
            # TP experts: capacity over fsdp, D replicated per shard — the
            # expert GEMM contracts D against fsdp-gathered weights and emits
            # (E, cap/fsdp, F/model) without resharding the buffer.  Measured
            # −53% collective bytes on mixtral train_4k (§Perf iteration 1).
            rules["moe_buf"] = NamedSharding(mesh, P(None, fsdp, None))
        # EP experts: forcing an E-sharded buffer makes GSPMD lower the
        # data-dependent scatter as replicate+all-reduce (+70% collective on
        # dbrx, §Perf iteration 3 — refuted); leave GSPMD's layout choice.
        d_ok = cfg.d_model % mesh.shape["model"] == 0
        rules["moe_out"] = NamedSharding(mesh, P(fsdp, "model" if d_ok else None))
    return rules


def logits_sharding(mesh: Mesh, cfg: ArchConfig) -> NamedSharding:
    fsdp = fsdp_axes(mesh)
    v_ok = cfg.vocab_size % mesh.shape["model"] == 0
    return NamedSharding(mesh, P(fsdp, None, "model" if v_ok else None))
