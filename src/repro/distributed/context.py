"""Activation-sharding context: how the launcher injects the residual-stream
constraint into model code without threading mesh objects through every
layer.  ``set_activation_sharding`` is called before tracing (dry-run,
trainer); ``constrain`` is a no-op when unset (single-device tests)."""
from __future__ import annotations

import contextlib
import threading

import jax

_tls = threading.local()


def set_activation_sharding(sharding) -> None:
    _tls.sharding = sharding


@contextlib.contextmanager
def activation_sharding(sharding):
    prev = getattr(_tls, "sharding", None)
    set_activation_sharding(sharding)
    try:
        yield
    finally:
        set_activation_sharding(prev)


def constrain(x: jax.Array) -> jax.Array:
    """Apply the residual-stream constraint to a (B, S, D) activation."""
    s = getattr(_tls, "sharding", None)
    if s is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, s)


# -- named internal-activation rules (set by the launcher per mesh/config) --


def set_sharding_rules(rules: dict | None) -> None:
    """rules: name -> jax.sharding.Sharding for named internal activations
    (e.g. 'moe_buf' for the MoE dispatch buffer).  Unset names are no-ops."""
    _tls.rules = rules or {}


def constrain_named(x: jax.Array, name: str) -> jax.Array:
    rules = getattr(_tls, "rules", None)
    if not rules or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])


# -- remat policy (set by the launcher; models read it at trace time) -------


def set_remat_policy(name: str | None) -> None:
    """'full' (default: recompute everything, save residual boundaries only)
    or 'dots' (save matmul outputs: −25% train compute for +activation HBM —
    pair with gradient accumulation; see EXPERIMENTS.md §Perf)."""
    _tls.remat_policy = name


def remat_policy():
    name = getattr(_tls, "remat_policy", None) or "full"
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable
