"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152. GQA + RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    layer_pattern=("G",),
    mlp_kind="gelu",   # starcoder2 uses a plain gelu MLP (4x)
    mlp_bias=True,
    pos="rope",
    source="[arXiv:2402.19173; hf]",
)
