"""whisper-medium [audio]: 24L d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=51865. Encoder-decoder; conv frontend is a STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,          # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    layer_pattern=("G",),
    mlp_kind="gelu",
    mlp_bias=True,
    pos="learned",
    encoder_layers=24,
    encoder_seq=1500,     # stub: precomputed mel-frame embeddings
    source="[arXiv:2212.04356; unverified]",
)
