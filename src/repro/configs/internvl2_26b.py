"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. InternViT frontend is a STUB (input_specs provides precomputed
patch embeddings); this config is the InternLM2 backbone.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    layer_pattern=("G",),
    mlp_kind="swiglu",
    pos="rope",
    vision_tokens=256,   # stub patch embeddings prepended to the sequence
    source="[arXiv:2404.16821; hf]",
)
