"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536.
Finch: data-dependent decay. [arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,        # wkv heads: d_model / head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=("R",),
    mlp_kind="gelu",   # channel-mix uses squared-relu; see models/rwkv.py
    pos="none",
    source="[arXiv:2404.05892; unverified]",
)
