"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Alternating local/global attention, logit softcapping. [arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    window=4096,
    layer_pattern=("L", "G"),   # local(4096) / global alternating
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_kind="geglu",
    pos="rope",
    tie_embeddings=True,
    source="[arXiv:2408.00118; hf]",
)
