"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig`` in its own module
(``src/repro/configs/<id>.py``) registered under its ``--arch`` id.  Shapes
are the four assigned input-shape cells; applicability rules (which cells run
for which arch) live here so the dry-run, benchmarks, and tests agree.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    moe_topk: int = 0
    # attention structure
    window: int = 0              # sliding/local window size (0 = full)
    layer_pattern: tuple[str, ...] = ("G",)  # repeated over depth:
    #   G=global attn block, L=local/SWA attn block, R=recurrent block
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    mlp_kind: str = "swiglu"     # swiglu | geglu | gelu
    mlp_bias: bool = False       # biases on MLP projections (starcoder2, whisper)
    pos: str = "rope"            # rope | learned | none
    rope_theta: float = 10000.0
    # encoder-decoder (whisper): encoder layers + stub frontend length
    encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm: stub patch-embedding tokens prepended to the text sequence
    vision_tokens: int = 0
    # recurrent dims
    rnn_width: int = 0           # RG-LRU width (griffin); rwkv uses d_model
    conv_width: int = 4          # temporal conv in griffin recurrent block
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""             # provenance note [source; verified-tier]

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- structure helpers ---------------------------------------------------
    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind for all n_layers (pattern repeated + remainder)."""
        pat = self.layer_pattern
        reps, rem = divmod(self.n_layers, len(pat))
        return pat * reps + pat[:rem]

    @property
    def is_attention_free(self) -> bool:
        return all(k == "R" for k in self.layer_kinds)

    @property
    def is_sub_quadratic(self) -> bool:
        """True if decode-state memory is bounded sub-linearly in context
        (recurrent state or windowed KV): gates the long_500k cell."""
        kinds = set(self.layer_kinds)
        if kinds <= {"R", "L"}:
            return True
        # global-attention layers present: sub-quadratic only if windowed
        return self.window > 0 and "G" not in kinds

    @property
    def has_global_full_attention(self) -> bool:
        return "G" in self.layer_kinds and self.window == 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
        if self.mlp_kind in ("swiglu", "geglu"):
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        total = 0
        for kind in self.layer_kinds:
            if kind == "R":
                if self.family == "ssm":  # rwkv6: time-mix ~5 proj + channel-mix
                    total += 5 * d * d + d * d + 2 * d * self.d_ff + self.d_ff * 0
                else:  # griffin recurrent block
                    w = self.rnn_width or d
                    total += 2 * d * w + w * d + self.conv_width * w + 3 * w
                total += mlp if self.family != "ssm" else 0
            else:
                if self.n_experts > 0:
                    total += qkv + d * self.n_experts + self.n_experts * mlp
                else:
                    total += qkv + mlp
            total += 2 * d  # norms
        total += self.vocab_size * d  # token embedding
        if not self.tie_embeddings:
            total += d * self.vocab_size
        if self.encoder_layers:
            total += self.encoder_layers * (qkv + (2 * d * ff) + 2 * d)
            total += self.n_layers * (qkv + 2 * d)  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6·N·D."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp = 3 * d * ff if self.mlp_kind in ("swiglu", "geglu") else 2 * d * ff
        dense = self.param_count() - self.n_layers * self.n_experts * mlp
        return dense + self.n_layers * self.moe_topk * mlp


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode" | "chunk_prefill" | "verify"
    # chunk_prefill / verify only: total cache context the slice attends
    # into (seq_len is the chunk / burst length itself).  0 elsewhere.
    ctx_len: int = 0


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: "ArchConfig", shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason). Mirrors DESIGN.md §4 applicability table."""
    if shape.name == "long_500k":
        kinds = set(arch.layer_kinds)
        if kinds <= {"R", "L"} or "R" in kinds or arch.window > 0:
            return True, "sub-quadratic decode state (recurrent/windowed layers)"
        if arch.name == "gemma2-2b":
            return True, "alternating local/global: not pure full-attention"
        return False, "pure full-attention arch: 500k KV decode skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "dbrx-132b",
    "mixtral-8x22b",
    "rwkv6-1.6b",
    "stablelm-12b",
    "starcoder2-7b",
    "gemma2-2b",
    "minitron-4b",
    "whisper-medium",
    "recurrentgemma-2b",
    "internvl2-26b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_REGISTRY: dict[str, ArchConfig] = {}


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        if name not in _MODULE_FOR:
            raise KeyError(f"unknown arch {name!r}; known: {list(_MODULE_FOR)}")
        mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
        _REGISTRY[name] = mod.CONFIG
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells() -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its applicability: (arch, shape, runs, reason)."""
    out = []
    for a in ARCH_IDS:
        arch = get_arch(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(arch, s)
            out.append((a, s.name, ok, why))
    return out


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants: same family/structure, tiny sizes.
# ---------------------------------------------------------------------------


def reduced(arch: ArchConfig) -> ArchConfig:
    pat = arch.layer_pattern
    n_layers = max(len(pat), 2)
    if arch.n_layers % len(pat):
        n_layers += arch.n_layers % len(pat)  # keep a remainder to exercise it
    head_dim = 16
    n_heads = max(2, min(4, arch.n_heads))
    n_kv = max(1, min(arch.n_kv_heads, n_heads))
    d_model = 64
    return dataclasses.replace(
        arch,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128,
        vocab_size=512,
        n_experts=min(arch.n_experts, 4),
        moe_topk=min(arch.moe_topk, 2),
        window=min(arch.window, 8) if arch.window else 0,
        encoder_layers=2 if arch.encoder_layers else 0,
        encoder_seq=16 if arch.encoder_seq else 0,
        vision_tokens=4 if arch.vision_tokens else 0,
        rnn_width=64 if arch.rnn_width else 0,
        dtype="float32",
    )
