"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1, MQA)
d_ff=7680 vocab=256000. RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    window=2048,
    layer_pattern=("R", "R", "L"),  # griffin: 2 recurrent then local attn
    mlp_kind="geglu",
    pos="rope",
    rnn_width=2560,
    conv_width=4,
    tie_embeddings=True,
    source="[arXiv:2402.19427; hf]",
)
