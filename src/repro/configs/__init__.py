from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    ShapeConfig,
    all_archs,
    all_cells,
    get_arch,
    get_shape,
    reduced,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "all_archs",
    "all_cells",
    "get_arch",
    "get_shape",
    "reduced",
    "shape_applicable",
]
