"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    moe_topk=2,
    window=4096,
    layer_pattern=("L",),  # SWA on every layer
    mlp_kind="swiglu",
    pos="rope",
    rope_theta=1000000.0,
    source="[arXiv:2401.04088; hf]",
)
