"""Quickstart: the paper's workflow in five steps.

    PYTHONPATH=src python examples/quickstart.py

1. auto-schedule a donor architecture (the expensive step you do ONCE);
2. persist its schedule database;
3. pick a donor for a new target with the Eq. 1 heuristic;
4. transfer-tune the target in seconds of (virtual) search;
5. run the target's kernels with the transferred schedules.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.database import ScheduleDB
from repro.core.tuner import arch_uses, donor_ranking, transfer_arch, tune_arch
from repro.kernels import ops
from repro.kernels.ops import ScheduleProvider

DB_PATH = "/tmp/repro_quickstart_db.json"


def main():
    db = ScheduleDB()

    print("== 1. auto-schedule donors (Ansor analogue; done once, offline) ==")
    for donor in ("dbrx-132b", "minitron-4b"):
        res = tune_arch(db, donor, "train_4k", dp=16, tp=16, total_trials=384)
        print(f"  {donor}: {res.untuned_seconds / res.tuned_seconds:.1f}x speedup "
              f"after {res.total_trials} trials ({res.search_time_s:.0f}s virtual search)")

    print("== 2. persist the schedule database ==")
    db.save(DB_PATH)
    print(f"  {len(db)} records -> {DB_PATH}")

    target = "mixtral-8x22b"
    print(f"== 3. donor selection for {target} (Eq. 1) ==")
    for ds in donor_ranking(db, target, "train_4k", dp=16, tp=16):
        print(f"  score {ds.score:.4f}  {ds.model_id}")

    print("== 4. transfer-tune the target ==")
    tt = transfer_arch(ScheduleDB.load(DB_PATH), target, "train_4k",
                       dp=16, tp=16, donors="auto")
    print(f"  speedup {tt.speedup:.2f}x  coverage {tt.coverage():.0%}  "
          f"search {tt.search_time_s:.0f}s (vs thousands for full tuning)")

    print("== 5. execute a kernel with its transferred schedule ==")
    provider = ScheduleProvider(tt.schedule_map())
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(48, 64)), jnp.float32)
    with ops.use_backend("pallas"):  # interpret-mode on CPU, compiled on TPU
        y = ops.matmul(x, w, provider=provider)
    err = float(jnp.abs(y - ops.matmul(x, w, backend="ref")).max())
    print(f"  pallas-vs-oracle max err: {err:.2e}")
    print("done.")


if __name__ == "__main__":
    main()
