"""Transfer-tuning deep dive: the paper's §4.1 GEMM example + Fig. 4 matrix.

    PYTHONPATH=src python examples/transfer_tuning_demo.py

Shows schedule anatomy (tiles / order / staging), cross-shape application,
invalid transfers, adaptive reformulation (beyond-paper), and the
per-kernel transfer matrix for a same-family arch pair.
"""
from repro.core.autoscheduler import tune_kernel
from repro.core.cost_model import kernel_seconds, measure
from repro.core.database import Record, ScheduleDB
from repro.core.schedule import default_schedule
from repro.core.transfer import transfer_matrix
from repro.core.tuner import arch_uses, tune_arch
from repro.core.workload import KernelInstance


def show_schedule(tag, sched):
    print(f"  {tag}: tiles={sched.t} order={sched.order} "
          f"unroll={sched.unroll} vec={sched.vec} cache_write={sched.cache_write}")


def main():
    print("== GEMM 512^3 vs 1024^3 (paper §4.1) ==")
    g = {s: KernelInstance.make("matmul", M=s, N=s, K=s) for s in (512, 1024)}
    tuned = {s: tune_kernel(g[s], trials=256) for s in (512, 1024)}
    for s in (512, 1024):
        u = kernel_seconds(g[s], default_schedule(g[s]))
        print(f"  {s}^3: untuned {u * 1e6:.1f}us -> tuned {tuned[s].best_seconds * 1e6:.1f}us "
              f"({u / tuned[s].best_seconds:.1f}x)")
        show_schedule(f"{s}^3 schedule", tuned[s].best)
    for src, dst in ((512, 1024), (1024, 512)):
        m = measure(g[dst], tuned[src].best, noise_sigma=0.0)
        if m.valid:
            print(f"  {src}->{dst} strict: {m.seconds * 1e6:.1f}us "
                  f"({m.seconds / tuned[dst].best_seconds:.2f}x of native)")
        else:
            print(f"  {src}->{dst} strict: INVALID (paper Fig. 4's -1)")
            ma = measure(g[dst], tuned[src].best, mode="adaptive", noise_sigma=0.0)
            print(f"  {src}->{dst} adaptive reformulation (beyond-paper): "
                  f"{ma.seconds * 1e6:.1f}us ({ma.seconds / tuned[dst].best_seconds:.2f}x of native)")

    print("\n== Fig. 4 analogue: mixtral-8x22b kernels x dbrx-132b schedules ==")
    db = ScheduleDB()
    tune_arch(db, "dbrx-132b", "train_4k", dp=16, tp=16, total_trials=384)
    uses = arch_uses("mixtral-8x22b", "train_4k", dp=16, tp=16)
    mat = transfer_matrix(uses, db, donors=["dbrx-132b"])
    for u in uses:
        row = mat[u.instance.workload_key()]
        untuned = kernel_seconds(u.instance)
        cells = " ".join(
            "-1" if s is None else f"{untuned / s:.2f}x" for s in row.values())
        print(f"  {u.tag:12s} [{u.instance.class_id:22s}] -> {cells or '(no donors)'}")


if __name__ == "__main__":
    main()
