"""End-to-end training driver: train a small LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~10M params, CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # ~100M params

Exercises the full production loop on real (synthetic-corpus) data:
deterministic sharded pipeline, AdamW with f32 masters + clipping + cosine
schedule, scan+remat model, async checkpointing with resume, straggler
monitor.  The loss curve is written to /tmp/repro_train_lm_loss.csv.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced
from repro.data import DataConfig, Pipeline
from repro.distributed import StragglerMonitor
from repro.launch import steps as steps_mod
from repro.models import build_model
from repro.optim.adamw import AdamWConfig

PRESETS = {
    # (d_model, n_layers, n_heads, kv, d_ff, vocab) ≈ params
    "10m": (256, 6, 4, 2, 1024, 4096),
    "100m": (768, 12, 12, 4, 3072, 16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    d, nl, h, kv, ff, v = PRESETS[args.preset]
    cfg = dataclasses.replace(
        reduced(get_arch("minitron-4b")),
        d_model=d, n_layers=nl, n_heads=h, n_kv_heads=kv, head_dim=d // h,
        d_ff=ff, vocab_size=v,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} v={cfg.vocab_size})")

    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=args.steps // 10,
                          total_steps=args.steps)
    step_fn = jax.jit(steps_mod.make_train_step(model, opt_cfg),
                      donate_argnums=(0, 1))
    opt_state = steps_mod.init_opt_state(params)
    data = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch))
    manager = CheckpointManager(args.ckpt, keep=2)
    monitor = StragglerMonitor()

    losses = []
    t_start = time.monotonic()
    for step, np_batch in data:
        if step >= args.steps:
            break
        t0 = time.monotonic()
        batch = {"tokens": jnp.asarray(np_batch["tokens"])}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        monitor.record(step, time.monotonic() - t0)
        if step % 20 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.2f}")
        if step and step % 100 == 0:
            manager.save(step, {"params": params, "opt": opt_state}, blocking=False)
    data.close()
    manager.save(len(losses), {"params": params, "opt": opt_state})
    manager.wait()

    dt = time.monotonic() - t_start
    with open("/tmp/repro_train_lm_loss.csv", "w") as f:
        f.writelines(f"{i},{l}\n" for i, l in enumerate(losses))
    print(f"\n{len(losses)} steps in {dt:.0f}s "
          f"({args.batch * args.seq * len(losses) / dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(min {min(losses):.4f}); stragglers flagged: {len(monitor.flagged)}")
    assert losses[-1] < losses[0], "training must reduce the loss"


if __name__ == "__main__":
    main()
