"""Cold-start serving with background schedule upgrades.

    PYTHONPATH=src python examples/serve_with_tuning.py

Demonstrates the online schedule-registry service end to end:

1. auto-schedule a *donor* arch and publish its records to a segmented
   :class:`~repro.service.ScheduleRegistry`;
2. serve a *target* arch's kernel stream cold through a
   :class:`~repro.service.TuningService` — first requests run untuned or on
   probed transfer candidates while background transfer-tuning jobs run on a
   worker pool;
3. watch later requests upgrade to exact hits as jobs publish, and print the
   service telemetry.

Everything is cost-model seconds (see DESIGN.md); no TPU required.
"""
import tempfile

from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.tuner import arch_uses, tune_arch_registry
from repro.service import ScheduleRegistry, TuningService

DONOR, TARGET = "internvl2-26b", "stablelm-12b"


def main():
    root = tempfile.mkdtemp(prefix="schedule-registry-")
    registry = ScheduleRegistry(root)

    print(f"tuning donor {DONOR} into registry at {root} ...")
    res = tune_arch_registry(registry, DONOR, dp=16, tp=16, total_trials=512)
    print(f"  {len(res.records)} records published, "
          f"generation {registry.generation}, donor speedup {res.speedup:.2f}x")

    service = TuningService(registry, model_id=TARGET, donors=[DONOR],
                            runner=CachedRunner(AnalyticalRunner()),
                            max_workers=2)
    uses = arch_uses(TARGET, dp=16, tp=16)
    untuned = sum(u.use_count * service.runner.seconds(u.instance, None)
                  for u in uses)
    print(f"\nserving {TARGET} cold ({len(uses)} kernels, "
          f"untuned {untuned * 1e3:.2f} model-ms):")
    for req in range(4):
        lookups = [service.lookup(u.instance) for u in uses]
        secs = sum(u.use_count * r.seconds for u, r in zip(uses, lookups))
        tiers = {t: sum(1 for r in lookups if r.tier == t)
                 for t in ("exact", "transfer", "default")}
        print(f"  request {req}: {secs * 1e3:.2f} model-ms  tiers={tiers}")
        if req == 1:
            # let the background jobs land mid-stream
            service.drain()
            print("  ... background transfer-tuning jobs drained ...")

    stats = service.stats()
    print(f"\nupgrades published: {stats['upgrades']}  "
          f"exact-hit rate: {stats['exact_hit_rate']:.2f}  "
          f"background search: {stats['search_seconds_spent']:.1f} virtual s  "
          f"registry generation: {stats['generation']}")
    service.close()

    # compaction folds the registry to its steady-state footprint
    before = registry.stats()
    registry.compact()
    after = registry.stats()
    print(f"compaction: {before['records']} records / {before['segments']} segments "
          f"-> {after['records']} records / {after['segments']} segment")


if __name__ == "__main__":
    main()
