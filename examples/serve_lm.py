"""Batched serving with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py

Runs a stream of variable-length requests through the slot-based engine
(requests join and leave mid-flight), for a dense arch and a sliding-window
arch (ring KV caches), reporting throughput.
"""
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serving import ServingEngine


def drive(arch: str, n_requests: int = 10, slots: int = 4):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, slots=slots, max_len=96)
    rng = np.random.default_rng(0)
    pending = [
        [int(t) for t in rng.integers(1, cfg.vocab_size, rng.integers(3, 12))]
        for _ in range(n_requests)
    ]
    done = []
    t0 = time.monotonic()
    steps = 0
    while pending or engine.active:
        while pending and engine.free_slots:
            engine.add_request(pending[0], max_new_tokens=int(rng.integers(4, 12)))
            pending.pop(0)
        done.extend(engine.step())
        steps += 1
    dt = time.monotonic() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"{arch:20s} {len(done)} requests, {toks} tokens, {steps} decode steps, "
          f"{toks / dt:.1f} tok/s (slots={slots})")


def main():
    drive("minitron-4b")        # dense, full KV caches
    drive("mixtral-8x22b")      # SWA: ring KV caches sized to the window
    drive("recurrentgemma-2b")  # hybrid: recurrent states + local attention


if __name__ == "__main__":
    main()
